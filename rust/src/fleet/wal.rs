//! The shard write-ahead log (`SDWL` v1): **one** durable file per shard.
//!
//! SEDAR level 2 protects the *application* by journaling recoverable
//! state as it goes; the fleet applies the same idea to the *validation
//! campaign*. Earlier builds kept two files per shard — a resume journal
//! (`SDJL`) appended as tasks finished, and a shard artifact (`SDSH`)
//! written at the end — two formats, two recovery paths, and a merge that
//! could only happen after the barrier. The WAL collapses both onto one
//! append-only stream:
//!
//! ```text
//! file     := header-record record*
//! record   := len u32 | crc32(body) u32 | body      (util::frame)
//! header   := "SDWL" | version u32 | seed u64 | shard u32 | of u32
//!             | total u64 | spec_hash u64
//! body     := tag u8 (0 = outcome, 1 = snapshot) | payload
//! outcome  := one TaskOutcome record (encode_outcome)
//! snapshot := count u64 | count × outcome records, ascending task index
//! ```
//!
//! As each task finishes, its [`TaskOutcome`] is appended as a tag-0
//! record and synced — a kill immediately after completion cannot lose the
//! record. Every `K` outcome records (and on clean shutdown), the full
//! known outcome set is appended as a tag-1 **snapshot**: the compaction
//! watermark. The reader ([`crate::fleet::snapshot`]) replays the stream,
//! resetting its state at each complete snapshot — so the last snapshot
//! supersedes the replayed prefix, a torn tail (including a kill **mid-
//! compaction**) merely falls back to the records before it, and resume,
//! completeness probing, merge and the live aggregate are all the same
//! read path. Recovery *is* replay.
//!
//! The header binds the file to one sweep — seed, shard plan and filtered
//! task total — so a stale WAL from a different seed or filter can never
//! leak foreign outcomes into a report. Old `SDJL`/`SDSH` files are
//! refused **by name** (and their readers are gone): the formats are not
//! convertible, and mis-decoding one would be worse than failing fast.

use std::collections::BTreeMap;
use std::fs::OpenOptions;
use std::io::{Seek, SeekFrom};
use std::path::Path;

use crate::campaign::shard::TaskOutcome;
use crate::campaign::{
    collective_from_ordinal, collective_ordinal, netfault_from_ordinal, netfault_ordinal,
    strategy_from_ordinal, strategy_ordinal, validation_from_ordinal, validation_ordinal,
    CampaignApp,
};
use crate::error::{FaultClass, Result, SedarError};
use crate::recovery::ResumeFrom;
use crate::util::frame::{self, next_record, push_string, ByteReader};

use super::snapshot::{self, ScanState};

pub use crate::campaign::aggregate::ShardMeta;

pub(crate) const MAGIC: &[u8; 4] = b"SDWL";
/// `SDWL` starts at 1: the WAL replaced the v4 `SDJL` journal + `SDSH`
/// artifact pair wholesale. A version bump here follows the same
/// discipline those formats did — any record-layout change bumps it, and
/// readers refuse other versions by name rather than mis-decode.
pub(crate) const VERSION: u32 = 1;
/// Record tag: one appended [`TaskOutcome`].
pub(crate) const TAG_OUTCOME: u8 = 0;
/// Record tag: a compaction snapshot (the full known outcome set).
pub(crate) const TAG_SNAPSHOT: u8 = 1;
/// Append a compaction snapshot after this many outcome records. Chosen so
/// a full 1152-task sweep compacts ~18 times: the replay a reader skips
/// stays short without bloating the log (total size is O(n²/K)).
pub const DEFAULT_SNAPSHOT_EVERY: usize = 64;

/// An open, append-positioned shard WAL.
pub struct Wal {
    file: std::fs::File,
    /// Every outcome the log currently proves, by task index — exactly
    /// what the next snapshot record will contain.
    known: BTreeMap<usize, TaskOutcome>,
    /// Outcome records appended since the last snapshot (the compaction
    /// counter; 0 means the tail is already compact).
    since_snapshot: usize,
    snapshot_every: usize,
}

pub(crate) fn header_body(meta: &ShardMeta) -> Vec<u8> {
    let mut b = Vec::with_capacity(40);
    b.extend_from_slice(MAGIC);
    b.extend_from_slice(&VERSION.to_le_bytes());
    b.extend_from_slice(&meta.seed.to_le_bytes());
    b.extend_from_slice(&meta.shard_index.to_le_bytes());
    b.extend_from_slice(&meta.shard_count.to_le_bytes());
    b.extend_from_slice(&meta.total_tasks.to_le_bytes());
    b.extend_from_slice(&meta.spec_hash.to_le_bytes());
    b
}

pub(crate) fn parse_header(body: &[u8]) -> Result<ShardMeta> {
    let mut r = ByteReader::new(body, "fleet WAL header");
    let magic = r.bytes(4)?;
    if magic != MAGIC {
        // Name the legacy formats explicitly: a v4-era fleet directory is
        // exactly what an operator upgrading in place will point us at.
        let legacy = match magic {
            b"SDJL" => Some("a fleet resume journal (SDJL)"),
            b"SDSH" => Some("a shard artifact payload (SDSH)"),
            b"SDTR" => Some("a trace log (SDTR)"),
            _ => None,
        };
        return Err(SedarError::Checkpoint(match legacy {
            Some(what) => format!(
                "not a fleet WAL: this is {what} — the SDWL v1 write-ahead log replaced \
                 the journal+artifact pair and this build reads neither old format; \
                 re-run the shard to produce a WAL"
            ),
            None => "not a fleet WAL (bad header magic)".to_string(),
        }));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(SedarError::Checkpoint(format!(
            "unsupported fleet WAL version {version} (this build reads \
             version {VERSION}) — delete the WAL to re-run the shard"
        )));
    }
    Ok(ShardMeta {
        seed: r.u64()?,
        shard_index: r.u32()?,
        shard_count: r.u32()?,
        total_tasks: r.u64()?,
        spec_hash: r.u64()?,
    })
}

impl Wal {
    /// Open (creating if absent) the WAL at `path` for `meta`'s sweep,
    /// with the default compaction interval.
    ///
    /// Returns the append-positioned WAL plus every outcome recovered from
    /// a previous run of the same shard (ascending task index). The valid
    /// prefix is kept; a torn tail record is truncated away. A WAL whose
    /// header names a different sweep (other seed, plan or filter width)
    /// is an error — as is a non-empty file that is not a WAL at all; this
    /// function never truncates a file it cannot positively identify as
    /// its own.
    pub fn open(path: &Path, meta: &ShardMeta) -> Result<(Wal, Vec<TaskOutcome>)> {
        Wal::open_with_interval(path, meta, DEFAULT_SNAPSHOT_EVERY)
    }

    /// [`Wal::open`] with an explicit compaction interval (`K` outcome
    /// records between snapshots; the crash-recovery tests use small `K`).
    pub fn open_with_interval(
        path: &Path,
        meta: &ShardMeta,
        snapshot_every: usize,
    ) -> Result<(Wal, Vec<TaskOutcome>)> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let existing = match std::fs::read(path) {
            Ok(data) => data,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e.into()),
        };

        let mut state = ScanState::fresh();
        if !existing.is_empty() {
            let (found, scanned) = snapshot::scan_wal(path, &existing)?;
            if found != *meta {
                let drift = if found.spec_hash != meta.spec_hash
                    && (found.seed, found.shard_index, found.shard_count, found.total_tasks)
                        == (meta.seed, meta.shard_index, meta.shard_count, meta.total_tasks)
                {
                    " — same seed and plan but a different --filter set"
                } else {
                    ""
                };
                return Err(SedarError::Checkpoint(format!(
                    "{}: WAL belongs to a different sweep \
                     (WAL seed {} shard {}/{} of {} tasks; \
                     this run is seed {} shard {}/{} of {} tasks){drift}",
                    path.display(),
                    found.seed,
                    found.shard_index + 1,
                    found.shard_count,
                    found.total_tasks,
                    meta.seed,
                    meta.shard_index + 1,
                    meta.shard_count,
                    meta.total_tasks
                )));
            }
            state = scanned;
        }

        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(false)
            .open(path)?;
        file.set_len(state.valid_len as u64)?;
        let mut wal = Wal {
            file,
            known: state.known,
            since_snapshot: state.since_snapshot,
            snapshot_every: snapshot_every.max(1),
        };
        wal.file.seek(SeekFrom::End(0))?;
        if state.valid_len == 0 {
            frame::write_record(&mut wal.file, &header_body(meta))?;
            // A fresh WAL's directory entry must survive a crash too:
            // without this, a kill right after creation can lose the whole
            // file even though every record inside it was synced.
            super::sync_parent_dir(path)?;
        }
        let recovered = wal.known.values().cloned().collect();
        Ok((wal, recovered))
    }

    /// Durably append one finished task (synced before returning, so a
    /// kill immediately after completion cannot lose the record), then
    /// compact if the interval is due.
    pub fn append(&mut self, outcome: &TaskOutcome) -> Result<()> {
        let mut body = Vec::with_capacity(136);
        body.push(TAG_OUTCOME);
        encode_outcome(outcome, &mut body);
        frame::write_record(&mut self.file, &body)?;
        self.known.insert(outcome.index, outcome.clone());
        self.since_snapshot += 1;
        if self.since_snapshot >= self.snapshot_every {
            self.write_snapshot()?;
        }
        Ok(())
    }

    fn write_snapshot(&mut self) -> Result<()> {
        let body = snapshot::encode_snapshot(&self.known);
        frame::write_record(&mut self.file, &body)?;
        self.since_snapshot = 0;
        Ok(())
    }

    /// Clean-shutdown compaction: append a final snapshot **only if**
    /// outcome records landed since the last one. A no-op resume over an
    /// already-compact WAL therefore appends nothing and leaves the file
    /// byte-identical — re-running a finished shard is provably free.
    pub fn finalize(&mut self) -> Result<()> {
        if self.since_snapshot > 0 {
            self.write_snapshot()?;
        }
        Ok(())
    }

    /// Outcomes the log currently proves (resumed ∪ appended).
    pub fn len(&self) -> usize {
        self.known.len()
    }

    pub fn is_empty(&self) -> bool {
        self.known.is_empty()
    }
}

fn fault_class_ordinal(c: FaultClass) -> u8 {
    match c {
        FaultClass::Tdc => 0,
        FaultClass::Fsc => 1,
        FaultClass::Le => 2,
        FaultClass::Toe => 3,
        FaultClass::CkptCorrupt => 4,
    }
}

fn fault_class_from_ordinal(ord: u8) -> Option<FaultClass> {
    [
        FaultClass::Tdc,
        FaultClass::Fsc,
        FaultClass::Le,
        FaultClass::Toe,
        FaultClass::CkptCorrupt,
    ]
    .into_iter()
    .find(|c| fault_class_ordinal(*c) == ord)
}

/// Append one outcome's binary record to `out`. Every field of
/// [`TaskOutcome`] round-trips — including the mismatch notes (arbitrary
/// UTF-8) and the informational wall time — so a merged report is
/// byte-identical to the single-process run's.
pub fn encode_outcome(o: &TaskOutcome, out: &mut Vec<u8>) {
    out.extend_from_slice(&(o.index as u64).to_le_bytes());
    out.extend_from_slice(&o.scenario_id.to_le_bytes());
    out.push(o.app.ordinal() as u8);
    out.push(strategy_ordinal(o.strategy) as u8);
    out.push(collective_ordinal(o.collectives) as u8);
    out.push(validation_ordinal(o.validation) as u8);
    out.push(netfault_ordinal(o.netfault) as u8);
    out.extend_from_slice(&o.faults.to_le_bytes());
    out.push(o.completed as u8);
    out.push(o.injected as u8);
    out.push(match o.correct {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    });
    out.extend_from_slice(&o.restarts.to_le_bytes());
    match &o.first_detection {
        None => out.push(0),
        Some((class, site)) => {
            out.push(1 + fault_class_ordinal(*class));
            push_string(out, site);
        }
    }
    match o.last_resume {
        None => out.push(0),
        Some(ResumeFrom::Scratch) => out.push(1),
        Some(ResumeFrom::SysCkpt(k)) => {
            out.push(2);
            out.extend_from_slice(&k.to_le_bytes());
        }
        Some(ResumeFrom::UserCkpt(k)) => {
            out.push(3);
            out.extend_from_slice(&k.to_le_bytes());
        }
    }
    out.push(o.pass as u8);
    out.extend_from_slice(&(o.mismatches.len() as u32).to_le_bytes());
    for m in &o.mismatches {
        push_string(out, m);
    }
    let wall_nanos = u64::try_from(o.wall.as_nanos()).unwrap_or(u64::MAX);
    out.extend_from_slice(&wall_nanos.to_le_bytes());
    // The observability counters, in MetricsSnapshot field order.
    for v in [
        o.metrics.compare_ticks,
        o.metrics.compare_bytes,
        o.metrics.sync_ticks,
        o.metrics.sync_events,
        o.metrics.sys_ckpt_ticks,
        o.metrics.sys_ckpt_bytes,
        o.metrics.sys_ckpts,
        o.metrics.user_ckpt_ticks,
        o.metrics.user_ckpt_bytes,
        o.metrics.user_ckpts,
        o.metrics.exec_ticks,
        o.metrics.execs,
        o.metrics.rollback_ticks,
        o.metrics.rollbacks,
    ] {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn bool_from(b: u8, what: &str) -> Result<bool> {
    match b {
        0 => Ok(false),
        1 => Ok(true),
        other => Err(SedarError::Checkpoint(format!(
            "{what}: bad bool byte {other}"
        ))),
    }
}

/// Decode one outcome record from `r`.
pub fn decode_outcome(r: &mut ByteReader<'_>) -> Result<TaskOutcome> {
    let what = r.what();
    let bad = |field: &str, v: u64| {
        SedarError::Checkpoint(format!("{what}: bad {field} ordinal {v}"))
    };
    let index = r.u64()? as usize;
    let scenario_id = r.u32()?;
    let app_ord = r.u8()? as u64;
    let app = CampaignApp::from_ordinal(app_ord).ok_or_else(|| bad("app", app_ord))?;
    let strat_ord = r.u8()? as u64;
    let strategy = strategy_from_ordinal(strat_ord).ok_or_else(|| bad("strategy", strat_ord))?;
    let coll_ord = r.u8()? as u64;
    let collectives =
        collective_from_ordinal(coll_ord).ok_or_else(|| bad("collectives", coll_ord))?;
    let val_ord = r.u8()? as u64;
    let validation = validation_from_ordinal(val_ord).ok_or_else(|| bad("validation", val_ord))?;
    let nf_ord = r.u8()? as u64;
    let netfault = netfault_from_ordinal(nf_ord).ok_or_else(|| bad("netfault", nf_ord))?;
    let faults = r.u32()?;
    let completed = bool_from(r.u8()?, what)?;
    let injected = bool_from(r.u8()?, what)?;
    let correct = match r.u8()? {
        0 => None,
        1 => Some(false),
        2 => Some(true),
        other => return Err(bad("correct", other as u64)),
    };
    let restarts = r.u32()?;
    let first_detection = match r.u8()? {
        0 => None,
        tag => {
            let class = fault_class_from_ordinal(tag - 1)
                .ok_or_else(|| bad("fault class", tag as u64))?;
            Some((class, r.string()?))
        }
    };
    let last_resume = match r.u8()? {
        0 => None,
        1 => Some(ResumeFrom::Scratch),
        2 => Some(ResumeFrom::SysCkpt(r.u64()?)),
        3 => Some(ResumeFrom::UserCkpt(r.u64()?)),
        other => return Err(bad("resume", other as u64)),
    };
    let pass = bool_from(r.u8()?, what)?;
    let n_mismatches = r.u32()?;
    if n_mismatches > 1 << 16 {
        return Err(SedarError::Checkpoint(format!(
            "{what}: implausible mismatch count {n_mismatches}"
        )));
    }
    let mut mismatches = Vec::with_capacity(n_mismatches as usize);
    for _ in 0..n_mismatches {
        mismatches.push(r.string()?);
    }
    let wall = std::time::Duration::from_nanos(r.u64()?);
    let metrics = crate::metrics::MetricsSnapshot {
        compare_ticks: r.u64()?,
        compare_bytes: r.u64()?,
        sync_ticks: r.u64()?,
        sync_events: r.u64()?,
        sys_ckpt_ticks: r.u64()?,
        sys_ckpt_bytes: r.u64()?,
        sys_ckpts: r.u64()?,
        user_ckpt_ticks: r.u64()?,
        user_ckpt_bytes: r.u64()?,
        user_ckpts: r.u64()?,
        exec_ticks: r.u64()?,
        execs: r.u64()?,
        rollback_ticks: r.u64()?,
        rollbacks: r.u64()?,
    };
    Ok(TaskOutcome {
        index,
        scenario_id,
        app,
        strategy,
        collectives,
        validation,
        netfault,
        faults,
        completed,
        restarts,
        injected,
        correct,
        first_detection,
        last_resume,
        pass,
        mismatches,
        wall,
        metrics,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Strategy;
    use crate::detect::ValidationMode;
    use crate::util::codec::crc32;

    fn meta() -> ShardMeta {
        ShardMeta {
            seed: 42,
            shard_index: 0,
            shard_count: 2,
            total_tasks: 8,
            spec_hash: 0xF1E7,
        }
    }

    fn outcome(index: usize) -> TaskOutcome {
        TaskOutcome {
            index,
            scenario_id: index as u32,
            app: CampaignApp::Matmul,
            strategy: Strategy::SysCkpt,
            collectives: crate::config::CollectiveImpl::PointToPoint,
            validation: ValidationMode::Full,
            netfault: crate::faultnet::NetFaultMode::None,
            faults: 1,
            completed: true,
            restarts: 0,
            injected: true,
            correct: Some(true),
            first_detection: None,
            last_resume: None,
            pass: true,
            mismatches: vec![],
            wall: std::time::Duration::ZERO,
            metrics: crate::metrics::MetricsSnapshot {
                compare_bytes: 64,
                sync_events: 2,
                execs: 1,
                ..Default::default()
            },
        }
    }

    fn sample(index: usize) -> TaskOutcome {
        TaskOutcome {
            index,
            scenario_id: 7,
            app: CampaignApp::Sw,
            strategy: Strategy::UserCkpt,
            collectives: crate::config::CollectiveImpl::Native,
            validation: ValidationMode::Sha256,
            netfault: crate::faultnet::NetFaultMode::Corrupt,
            faults: 2,
            completed: true,
            restarts: 1,
            injected: true,
            correct: Some(true),
            first_detection: Some((FaultClass::Tdc, "GATHER|rank1".into())),
            last_resume: Some(ResumeFrom::UserCkpt(3)),
            pass: false,
            mismatches: vec!["ошибка №1 — 错误".into(), String::new()],
            wall: std::time::Duration::from_micros(1234),
            metrics: crate::metrics::MetricsSnapshot {
                compare_ticks: 1,
                compare_bytes: 2,
                sync_ticks: 3,
                sync_events: 4,
                sys_ckpt_ticks: 5,
                sys_ckpt_bytes: 6,
                sys_ckpts: 7,
                user_ckpt_ticks: 8,
                user_ckpt_bytes: 9,
                user_ckpts: 10,
                exec_ticks: 11,
                execs: 12,
                rollback_ticks: 13,
                rollbacks: 14,
            },
        }
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "sedar-wal-{tag}-{}-{:?}.wal",
            std::process::id(),
            std::thread::current().id()
        ))
    }

    #[test]
    fn record_roundtrip() {
        let mut buf = Vec::new();
        encode_outcome(&sample(42), &mut buf);
        let mut r = ByteReader::new(&buf, "test");
        let back = decode_outcome(&mut r).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!(format!("{:?}", back), format!("{:?}", sample(42)));
    }

    #[test]
    fn decode_rejects_bad_ordinals_and_truncation() {
        let mut buf = Vec::new();
        encode_outcome(&sample(1), &mut buf);
        // Truncation at every prefix must error, never panic.
        for cut in 0..buf.len() {
            let mut r = ByteReader::new(&buf[..cut], "test");
            assert!(decode_outcome(&mut r).is_err(), "prefix {cut} decoded");
        }
        // Corrupt the app ordinal (offset 12: u64 index + u32 scenario).
        let mut bad = buf.clone();
        bad[12] = 99;
        assert!(decode_outcome(&mut ByteReader::new(&bad, "test")).is_err());
    }

    #[test]
    fn append_then_recover() {
        let p = tmp("roundtrip");
        let _ = std::fs::remove_file(&p);
        {
            let (mut w, recovered) = Wal::open(&p, &meta()).unwrap();
            assert!(recovered.is_empty());
            w.append(&outcome(0)).unwrap();
            w.append(&outcome(2)).unwrap();
        }
        let (_, recovered) = Wal::open(&p, &meta()).unwrap();
        let idx: Vec<usize> = recovered.iter().map(|o| o.index).collect();
        assert_eq!(idx, vec![0, 2]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn torn_tail_is_dropped() {
        let p = tmp("torn");
        let _ = std::fs::remove_file(&p);
        {
            let (mut w, _) = Wal::open(&p, &meta()).unwrap();
            w.append(&outcome(0)).unwrap();
            w.append(&outcome(2)).unwrap();
        }
        // Simulate a crash mid-append: chop bytes off the last record.
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..data.len() - 5]).unwrap();
        let (mut w, recovered) = Wal::open(&p, &meta()).unwrap();
        assert_eq!(recovered.len(), 1);
        assert_eq!(recovered[0].index, 0);
        // The WAL must be appendable after truncation, and the new record
        // must land cleanly where the torn one was.
        w.append(&outcome(4)).unwrap();
        drop(w);
        let (_, recovered) = Wal::open(&p, &meta()).unwrap();
        let idx: Vec<usize> = recovered.iter().map(|o| o.index).collect();
        assert_eq!(idx, vec![0, 4]);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn compaction_snapshots_are_the_watermark() {
        let p = tmp("compact");
        let _ = std::fs::remove_file(&p);
        {
            let (mut w, _) = Wal::open_with_interval(&p, &meta(), 2).unwrap();
            for i in [0, 2, 4, 6, 1] {
                w.append(&outcome(i)).unwrap();
            }
            // 5 appends at K=2 → snapshots after outcomes 2 and 6; index 1
            // rides uncompacted behind the last watermark.
            w.finalize().unwrap();
        }
        let (_, recovered) = Wal::open_with_interval(&p, &meta(), 2).unwrap();
        let idx: Vec<usize> = recovered.iter().map(|o| o.index).collect();
        assert_eq!(idx, vec![0, 1, 2, 4, 6], "replay through snapshots lost state");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn kill_mid_compaction_recovers_from_the_last_watermark() {
        let p = tmp("midcompact");
        let _ = std::fs::remove_file(&p);
        {
            let (mut w, _) = Wal::open_with_interval(&p, &meta(), 2).unwrap();
            for i in [0, 2, 4, 6] {
                w.append(&outcome(i)).unwrap();
            }
        }
        // The file now ends with the K=4 snapshot {0,2,4,6}. Tear INTO that
        // snapshot record (a SIGKILL mid-compaction): the reader must fall
        // back to the records before it — nothing is lost, because a
        // snapshot only ever repeats what outcome records already proved.
        let data = std::fs::read(&p).unwrap();
        std::fs::write(&p, &data[..data.len() - 9]).unwrap();
        let (_, recovered) = Wal::open_with_interval(&p, &meta(), 2).unwrap();
        let idx: Vec<usize> = recovered.iter().map(|o| o.index).collect();
        assert_eq!(idx, vec![0, 2, 4, 6], "mid-compaction tear lost outcomes");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn live_tailing_reingest_is_idempotent_replacement() {
        use super::snapshot::read_wal;
        use crate::campaign::aggregate::IncrementalMerger;
        let p = tmp("tail");
        let _ = std::fs::remove_file(&p);
        // One shard covering the whole sweep, so the merged union closes.
        let m = ShardMeta {
            seed: 42,
            shard_index: 0,
            shard_count: 1,
            total_tasks: 4,
            spec_hash: 0xF1E7,
        };
        let (mut w, _) = Wal::open(&p, &m).unwrap();
        w.append(&outcome(0)).unwrap();
        w.append(&outcome(1)).unwrap();
        // A live tailer (the gateway's aggregate) reads mid-append…
        let mut live = IncrementalMerger::new(m);
        let (found, prefix) = read_wal(&p).unwrap();
        assert_eq!(prefix.len(), 2);
        live.ingest(&found, prefix).unwrap();
        assert_eq!(live.done(), 2);
        assert!(!live.is_complete());
        // …the shard keeps appending and finishes…
        w.append(&outcome(2)).unwrap();
        w.append(&outcome(3)).unwrap();
        w.finalize().unwrap();
        drop(w);
        // …and the tailer re-ingests the SAME WAL in full. Ingest must be
        // idempotent replacement of that shard's slot, not accumulation.
        let (found, full) = read_wal(&p).unwrap();
        live.ingest(&found, full).unwrap();
        assert_eq!(live.done(), 4);
        assert!(live.is_complete());
        // The prefix-then-full merger must be byte-identical to a fresh
        // single full ingest — the serve report path depends on it.
        let mut fresh = IncrementalMerger::new(m);
        let (found, full) = read_wal(&p).unwrap();
        fresh.ingest(&found, full).unwrap();
        assert_eq!(
            format!("{:?}", live.merged().unwrap()),
            format!("{:?}", fresh.merged().unwrap())
        );
        assert_eq!(
            live.report().unwrap().deterministic_report(),
            fresh.report().unwrap().deterministic_report()
        );
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn noop_resume_leaves_the_file_byte_identical() {
        let p = tmp("noop");
        let _ = std::fs::remove_file(&p);
        {
            let (mut w, _) = Wal::open(&p, &meta()).unwrap();
            w.append(&outcome(0)).unwrap();
            w.append(&outcome(2)).unwrap();
            w.finalize().unwrap();
        }
        let before = std::fs::read(&p).unwrap();
        {
            // A resume that executes nothing: recover, finalize, exit. The
            // tail is already compact, so finalize must append NOTHING —
            // the CI wal-smoke job `cmp`s exactly this.
            let (mut w, recovered) = Wal::open(&p, &meta()).unwrap();
            assert_eq!(recovered.len(), 2);
            w.finalize().unwrap();
        }
        assert_eq!(std::fs::read(&p).unwrap(), before);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn created_wal_in_fresh_directory_reopens() {
        // Creation in a freshly made nested directory exercises the
        // create → header write → parent-directory fsync path; the reopen
        // proves the WAL those steps left behind is well-formed.
        let dir = std::env::temp_dir().join(format!(
            "sedar-wal-dirsync-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let p = dir.join("deep").join("sweep.wal");
        {
            let (mut w, recovered) = Wal::open(&p, &meta()).unwrap();
            assert!(recovered.is_empty());
            w.append(&outcome(0)).unwrap();
        }
        let (_, recovered) = Wal::open(&p, &meta()).unwrap();
        assert_eq!(recovered.len(), 1);
        assert_eq!(recovered[0].index, 0);
        // The helper itself must tolerate a parentless (cwd-relative)
        // path — it syncs "." rather than erroring.
        crate::fleet::sync_parent_dir(std::path::Path::new("bare-name.wal")).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn foreign_sweep_rejected() {
        let p = tmp("foreign");
        let _ = std::fs::remove_file(&p);
        {
            let (mut w, _) = Wal::open(&p, &meta()).unwrap();
            w.append(&outcome(0)).unwrap();
        }
        let mut other = meta();
        other.seed = 43;
        assert!(Wal::open(&p, &other).is_err());
        let mut other = meta();
        other.shard_index = 1;
        assert!(Wal::open(&p, &other).is_err());
        // Same seed and plan but a different filter set (spec fingerprint).
        let mut other = meta();
        other.spec_hash = 0xDEAD;
        let err = Wal::open(&p, &other).unwrap_err();
        assert!(err.to_string().contains("--filter"), "got: {err}");
        // A non-WAL file is refused, not truncated.
        std::fs::write(&p, b"definitely not a WAL").unwrap();
        assert!(Wal::open(&p, &meta()).is_err());
        assert_eq!(std::fs::read(&p).unwrap(), b"definitely not a WAL");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn legacy_journal_and_artifact_are_refused_by_name() {
        // A v4-era SDJL journal header: framed exactly as this reader
        // frames, but the magic names the retired format. The error must
        // name BOTH formats, and the file must not be modified.
        let p = tmp("legacy-journal");
        let _ = std::fs::remove_file(&p);
        let mut body = Vec::new();
        body.extend_from_slice(b"SDJL");
        body.extend_from_slice(&4u32.to_le_bytes());
        body.extend_from_slice(&[0u8; 32]);
        let mut rec = Vec::new();
        rec.extend_from_slice(&(body.len() as u32).to_le_bytes());
        rec.extend_from_slice(&crc32(&body).to_le_bytes());
        rec.extend_from_slice(&body);
        std::fs::write(&p, &rec).unwrap();
        let err = Wal::open(&p, &meta()).unwrap_err().to_string();
        assert!(err.contains("SDJL"), "missing legacy format name: {err}");
        assert!(err.contains("SDWL"), "missing reader format name: {err}");
        assert_eq!(std::fs::read(&p).unwrap(), rec, "legacy journal was modified");
        std::fs::remove_file(&p).unwrap();

        // A legacy SDSH artifact rode inside an SDCK checkpoint frame, so
        // the raw file leads with the container's magic — also refused by
        // name, also untouched.
        let p = tmp("legacy-artifact");
        let _ = std::fs::remove_file(&p);
        let fake = b"SDCK then whatever the frame held".to_vec();
        std::fs::write(&p, &fake).unwrap();
        let err = Wal::open(&p, &meta()).unwrap_err().to_string();
        assert!(err.contains("SDSH") || err.contains("SDCK"), "{err}");
        assert!(err.contains("SDWL"), "missing reader format name: {err}");
        assert_eq!(std::fs::read(&p).unwrap(), fake, "legacy artifact was modified");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn version_drift_is_refused_naming_both_versions() {
        // A hand-built WAL whose header claims version 2: the reader must
        // refuse it naming both versions, and must NOT truncate it.
        let p = tmp("v2");
        let _ = std::fs::remove_file(&p);
        let mut body = Vec::new();
        body.extend_from_slice(MAGIC);
        body.extend_from_slice(&2u32.to_le_bytes());
        body.extend_from_slice(&meta().seed.to_le_bytes());
        body.extend_from_slice(&meta().shard_index.to_le_bytes());
        body.extend_from_slice(&meta().shard_count.to_le_bytes());
        body.extend_from_slice(&meta().total_tasks.to_le_bytes());
        body.extend_from_slice(&meta().spec_hash.to_le_bytes());
        let mut rec = Vec::new();
        rec.extend_from_slice(&(body.len() as u32).to_le_bytes());
        rec.extend_from_slice(&crc32(&body).to_le_bytes());
        rec.extend_from_slice(&body);
        std::fs::write(&p, &rec).unwrap();
        let err = Wal::open(&p, &meta()).unwrap_err().to_string();
        assert!(err.contains("version 2"), "missing file version: {err}");
        assert!(err.contains("version 1"), "missing reader version: {err}");
        assert_eq!(std::fs::read(&p).unwrap(), rec, "v2 WAL was modified");
        std::fs::remove_file(&p).unwrap();
    }
}
