//! The execution parameters of Table 1 / Table 3.

/// All quantities in **seconds** (the paper mixes hours and seconds; we
/// normalize and format on output).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Params {
    /// `T_prog`: execution time of two simultaneous instances of the
    /// original application (the baseline's parallel run).
    pub t_prog: f64,
    /// `T_comp`: semi-automatic final-result comparison time.
    pub t_comp: f64,
    /// `f_d`: detection-mechanism overhead factor (0 < f_d < 1).
    pub f_d: f64,
    /// `t_i`: checkpoint interval.
    pub t_i: f64,
    /// `n`: number of checkpoints over the whole execution.
    pub n: u32,
    /// `t_cs`: time to store one system-level checkpoint.
    pub t_cs: f64,
    /// `T_rest`: restart time.
    pub t_rest: f64,
    /// `t_ca`: time to store one application-level checkpoint.
    pub t_ca: f64,
    /// `T_compA`: time to validate one application-level checkpoint.
    pub t_comp_a: f64,
    /// `W`: checkpointed workload size in MB (reported, not used in
    /// equations — it *drives* `t_cs` physically).
    pub w_mb: f64,
}

const H: f64 = 3600.0;

/// The three benchmark applications of Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PaperApp {
    Matmul,
    Jacobi,
    Sw,
}

impl PaperApp {
    pub const ALL: [PaperApp; 3] = [PaperApp::Matmul, PaperApp::Jacobi, PaperApp::Sw];

    pub fn label(self) -> &'static str {
        match self {
            PaperApp::Matmul => "MATMUL",
            PaperApp::Jacobi => "JACOBI",
            PaperApp::Sw => "SW",
        }
    }

    /// The published Table 3 values.
    pub fn paper_params(self) -> Params {
        match self {
            PaperApp::Matmul => Params {
                t_prog: 10.21 * H,
                t_comp: 42.0,
                f_d: 0.0001, // "< 0.01 %"
                t_i: 1.0 * H,
                n: 10,
                t_cs: 14.10,
                t_rest: 14.10,
                t_ca: 10.58,
                t_comp_a: 42.0,
                w_mb: 6016.0,
            },
            PaperApp::Jacobi => Params {
                t_prog: 8.92 * H,
                t_comp: 1.0,
                f_d: 0.006, // 0.6 %
                t_i: 1.0 * H,
                n: 8,
                t_cs: 9.62,
                t_rest: 9.62,
                t_ca: 9.11,
                t_comp_a: 1.0,
                w_mb: 1920.0,
            },
            PaperApp::Sw => Params {
                t_prog: 11.15 * H,
                t_comp: 0.5, // "< 1 s"
                f_d: 0.0005, // 0.05 %
                t_i: 1.0 * H,
                n: 11,
                t_cs: 2.55,
                t_rest: 2.55,
                t_ca: 1.92,
                t_comp_a: 0.5,
                w_mb: 152.0,
            },
        }
    }
}

impl Params {
    /// §4.3: `n` is obtained by dividing the detection-only execution time
    /// (Equation 3) by the checkpoint interval.
    pub fn derive_n(&self) -> u32 {
        let t_fa = self.t_prog * (1.0 + self.f_d) + self.t_comp;
        (t_fa / self.t_i).floor() as u32
    }

    /// Replace `n` by its derived value.
    pub fn with_derived_n(mut self) -> Params {
        self.n = self.derive_n();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_n_matches_table3() {
        // Table 3 lists n = 10 / 8 / 11 for t_i = 1 h.
        assert_eq!(PaperApp::Matmul.paper_params().derive_n(), 10);
        assert_eq!(PaperApp::Jacobi.paper_params().derive_n(), 8);
        assert_eq!(PaperApp::Sw.paper_params().derive_n(), 11);
    }

    #[test]
    fn labels() {
        assert_eq!(PaperApp::Matmul.label(), "MATMUL");
        assert_eq!(PaperApp::ALL.len(), 3);
    }
}
