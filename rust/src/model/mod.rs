//! The analytical temporal model of the paper (§3.1–3.4, §4.3–4.4).
//!
//! Equations 1–14 describe the execution time of every strategy with and
//! without a fault; Equations 9–11 average them by fault probability (AET).
//! Tables 4 and 5 of the paper are *evaluations of this model* over the
//! measured parameters of Table 3 — so this module, fed the paper's
//! parameter values, regenerates the paper's numbers exactly (checked to
//! rounding tolerance in `rust/tests/model_paper_values.rs`), and fed our
//! measured parameters regenerates the same *shapes* on this host.

pub mod aet;
pub mod equations;
pub mod params;
pub mod tables;

pub use aet::{aet, daly_interval, fault_probability};
pub use equations::*;
pub use params::{Params, PaperApp};
pub use tables::{table4, table5, threshold_x, Table4Row, Table5};
