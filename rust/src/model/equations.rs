//! Equations 1–8 and 13–14 of the paper: per-strategy execution time in the
//! absence (`*_fa`) and presence (`*_fp`) of a single silent fault.
//!
//! All times in seconds; `x` is the detection instant as a fraction of
//! progress (0 < X < 1); `k` is the number of *additional* checkpoints the
//! recovery must walk back (k = 0 ⇒ the last checkpoint works).

use super::params::Params;

/// Equation 1 — baseline, fault-free: two simultaneous instances + final
/// comparison.
pub fn eq1_baseline_fa(p: &Params) -> f64 {
    p.t_prog + p.t_comp
}

/// Equation 2 — baseline with a fault: full re-execution + second
/// comparison (vote) + a restart.
pub fn eq2_baseline_fp(p: &Params) -> f64 {
    2.0 * (p.t_prog + p.t_comp) + p.t_rest
}

/// Equation 3 — detection-only, fault-free: the baseline time with `T_prog`
/// inflated by the detection overhead factor `f_d`.
pub fn eq3_detect_fa(p: &Params) -> f64 {
    p.t_prog * (1.0 + p.f_d) + p.t_comp
}

/// Equation 4 — detection-only with a fault detected at progress `x`:
/// the executed fraction + a full re-execution + restart + comparison.
pub fn eq4_detect_fp(p: &Params, x: f64) -> f64 {
    p.t_prog * (1.0 + p.f_d) * (x + 1.0) + p.t_rest + p.t_comp
}

/// Equation 5 — multiple system-level checkpoints, fault-free: detection
/// overhead plus `n` checkpoint stores.
pub fn eq5_sys_fa(p: &Params) -> f64 {
    p.t_prog * (1.0 + p.f_d) + p.t_comp + p.n as f64 * p.t_cs
}

/// Equation 13 — the re-execution series of Equation 6 in closed form:
/// `Σ_{m=0}^{k} (k - m + 1/2) · t_i = (k+1)²/2 · t_i`.
pub fn eq13_rework(k: u32, t_i: f64) -> f64 {
    let k1 = (k + 1) as f64;
    k1 * k1 / 2.0 * t_i
}

/// Equation 6 / 14 — multiple system-level checkpoints with a fault needing
/// `k` extra rollbacks: base time + re-stored checkpoints + re-executed
/// intervals + restarts.
pub fn eq6_sys_fp(p: &Params, k: u32) -> f64 {
    p.t_prog * (1.0 + p.f_d)
        + p.t_comp
        + (p.n + k) as f64 * p.t_cs
        + eq13_rework(k, p.t_i)
        + (k + 1) as f64 * p.t_rest
}

/// Equation 7 — single validated application-level checkpoint, fault-free:
/// detection overhead plus `n` validated user-level checkpoints.
pub fn eq7_user_fa(p: &Params) -> f64 {
    p.t_prog * (1.0 + p.f_d) + p.t_comp + p.n as f64 * (p.t_ca + p.t_comp_a)
}

/// Equation 8 — single validated application-level checkpoint with a fault:
/// on average half a checkpoint interval is re-executed and exactly one
/// restart happens.
pub fn eq8_user_fp(p: &Params) -> f64 {
    eq7_user_fa(p) + 0.5 * p.t_i + p.t_rest
}

/// Equation 12 (rearranged) — the measured detection overhead factor from a
/// SEDAR detection run vs the baseline:
/// `f_d = (T_SEDAR_det_FA - (T_prog + T_comp)) / (T_prog + T_comp)`.
pub fn eq12_f_d(t_sedar_det_fa: f64, t_prog: f64, t_comp: f64) -> f64 {
    (t_sedar_det_fa - (t_prog + t_comp)) / (t_prog + t_comp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::PaperApp;

    const H: f64 = 3600.0;

    fn close(a_hours: f64, b_hours: f64, tol: f64) {
        assert!(
            (a_hours - b_hours).abs() <= tol,
            "expected {b_hours:.3} h, got {a_hours:.3} h"
        );
    }

    // These spot-check the equations against Table 4 of the paper; the full
    // sweep lives in rust/tests/model_paper_values.rs.

    #[test]
    fn eq1_matches_table4_row1() {
        let p = PaperApp::Matmul.paper_params();
        close(eq1_baseline_fa(&p) / H, 10.22, 0.015);
    }

    #[test]
    fn eq6_k0_matches_table4_row8() {
        let p = PaperApp::Matmul.paper_params();
        close(eq6_sys_fp(&p, 0) / H, 10.77, 0.015);
    }

    #[test]
    fn eq13_closed_form_equals_series() {
        for k in 0..8u32 {
            let series: f64 = (0..=k).map(|m| (k - m) as f64 + 0.5).sum::<f64>();
            assert!((eq13_rework(k, 1.0) - series).abs() < 1e-12);
        }
    }

    #[test]
    fn eq8_close_to_eq6_k0() {
        // §4.3: "the time of recovery from the last valid application-level
        // checkpoint is almost equal to the time of recovery from the last
        // system-level checkpoint".
        for app in PaperApp::ALL {
            let p = app.paper_params();
            let d = (eq8_user_fp(&p) - eq6_sys_fp(&p, 0)).abs() / H;
            assert!(d < 0.15, "{}: diff {d:.3} h", app.label());
        }
    }

    #[test]
    fn eq12_recovers_overhead_factor() {
        let p = PaperApp::Jacobi.paper_params();
        let t_det = eq3_detect_fa(&p);
        let f = eq12_f_d(t_det, p.t_prog, p.t_comp);
        // Round-trips f_d up to the T_comp/T_prog cross-term.
        assert!((f - p.f_d).abs() < 1e-4);
    }
}
