//! Average Execution Time (§3.4, Equations 9–11) and Daly's optimal
//! checkpoint interval (referenced in §4.3).

/// Equation 10 — probability that a silent fault hits a computation of
/// length `t_prog` on a system with the given `mtbe` (exponential errors):
/// `α = 1 - e^(-T_prog / MTBE)`.
pub fn fault_probability(t_prog: f64, mtbe: f64) -> f64 {
    1.0 - (-t_prog / mtbe).exp()
}

/// Equations 9 + 11 — `AET = T_FP·α + T_FA·(1-α)` with α from the MTBE.
pub fn aet(t_fa: f64, t_fp: f64, t_prog: f64, mtbe: f64) -> f64 {
    let alpha = fault_probability(t_prog, mtbe);
    t_fp * alpha + t_fa * (1.0 - alpha)
}

/// MTBE of an N-processor system from the per-processor MTBE (§3.4:
/// `MTBE = MTBE_ind / N`).
pub fn system_mtbe(mtbe_ind: f64, n_processors: u32) -> f64 {
    mtbe_ind / n_processors as f64
}

/// Daly's higher-order estimate of the optimum checkpoint interval
/// (J. T. Daly, FGCS 2006), for checkpoint cost `delta` and MTBF `m`:
///
/// `t_opt = sqrt(2δM)·[1 + (1/3)√(δ/2M) + (1/9)(δ/2M)] − δ`  for δ < 2M,
/// `t_opt = M` otherwise.
pub fn daly_interval(delta: f64, m: f64) -> f64 {
    if delta >= 2.0 * m {
        return m;
    }
    let r = delta / (2.0 * m);
    (2.0 * delta * m).sqrt() * (1.0 + r.sqrt() / 3.0 + r / 9.0) - delta
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probability_limits() {
        assert!(fault_probability(1.0, 1e12) < 1e-9); // huge MTBE → ~0
        assert!(fault_probability(1e12, 1.0) > 0.999999); // tiny MTBE → ~1
        let p = fault_probability(3600.0, 3600.0);
        assert!((p - (1.0 - (-1.0f64).exp())).abs() < 1e-12);
    }

    #[test]
    fn aet_interpolates_between_fa_and_fp() {
        let t_fa = 10.0;
        let t_fp = 20.0;
        // Fault certain → FP time; fault impossible → FA time.
        assert!((aet(t_fa, t_fp, 1e12, 1.0) - t_fp).abs() < 1e-3);
        assert!((aet(t_fa, t_fp, 1.0, 1e12) - t_fa).abs() < 1e-3);
        // Monotone in fault probability: smaller MTBE → larger AET.
        let a1 = aet(t_fa, t_fp, 10.0, 100.0);
        let a2 = aet(t_fa, t_fp, 10.0, 10.0);
        assert!(a2 > a1);
    }

    #[test]
    fn system_mtbe_scales_inversely() {
        assert_eq!(system_mtbe(1000.0, 10), 100.0);
    }

    #[test]
    fn daly_reasonable() {
        // First-order term dominates: t_opt ≈ sqrt(2 δ M).
        let t = daly_interval(10.0, 24.0 * 3600.0);
        let first_order = (2.0f64 * 10.0 * 24.0 * 3600.0).sqrt();
        assert!((t - first_order).abs() / first_order < 0.05);
        // Degenerate regime.
        assert_eq!(daly_interval(100.0, 10.0), 10.0);
    }
}
