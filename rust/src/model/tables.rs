//! Generators for the paper's evaluation tables.
//!
//! * [`table4`] — Table 4: execution times of every strategy × fault
//!   situation for a parameter set (12 rows).
//! * [`table5`] — Table 5: detection-only vs `k+1` rollback attempts for
//!   X ∈ {30, 50, 80}% with the NA (not-admissible) logic of §4.4.
//! * [`threshold_x`] — the §4.4 crossover points (5.88 %, 22.67 %, 50.61 %
//!   for the Jacobi parameters).

use super::equations::*;
use super::params::Params;

/// One row of Table 4.
#[derive(Debug, Clone)]
pub struct Table4Row {
    pub label: String,
    /// Time in hours, one per app column.
    pub hours: Vec<f64>,
}

/// Regenerate Table 4 for a set of app parameter columns.
/// Rows match the paper exactly (X ∈ {30, 50, 80} %, k ∈ {0, 1, 4}).
pub fn table4(params: &[(&str, Params)]) -> Vec<Table4Row> {
    const H: f64 = 3600.0;
    let mut rows: Vec<(String, Box<dyn Fn(&Params) -> f64>)> = Vec::new();
    rows.push((
        "Baseline, without fault (Eq. 1)".into(),
        Box::new(|p| eq1_baseline_fa(p)),
    ));
    rows.push((
        "Baseline, with fault (Eq. 2)".into(),
        Box::new(|p| eq2_baseline_fp(p)),
    ));
    rows.push((
        "Only detection, without fault (Eq. 3)".into(),
        Box::new(|p| eq3_detect_fa(p)),
    ));
    for x in [0.3, 0.5, 0.8] {
        rows.push((
            format!("Only detection, with fault (Eq. 4, X = {:.0}%)", x * 100.0),
            Box::new(move |p| eq4_detect_fp(p, x)),
        ));
    }
    rows.push((
        "Multiple checkpoints, without fault (Eq. 5)".into(),
        Box::new(|p| eq5_sys_fa(p)),
    ));
    for k in [0u32, 1, 4] {
        rows.push((
            format!("Multiple checkpoints, with fault (Eq. 6, k = {k})"),
            Box::new(move |p| eq6_sys_fp(p, k)),
        ));
    }
    rows.push((
        "Single checkpoint, without fault (Eq. 7)".into(),
        Box::new(|p| eq7_user_fa(p)),
    ));
    rows.push((
        "Single checkpoint, with fault (Eq. 8)".into(),
        Box::new(|p| eq8_user_fp(p)),
    ));

    rows.into_iter()
        .map(|(label, f)| Table4Row {
            label,
            hours: params.iter().map(|(_, p)| f(p) / H).collect(),
        })
        .collect()
}

/// Markdown rendering of Table 4.
pub fn table4_markdown(params: &[(&str, Params)]) -> String {
    let mut s = String::from("| # | Situation |");
    for (name, _) in params {
        s.push_str(&format!(" {name} |"));
    }
    s.push_str("\n|---|---|");
    for _ in params {
        s.push_str("---|");
    }
    s.push('\n');
    for (i, row) in table4(params).iter().enumerate() {
        s.push_str(&format!("| {} | {} |", i + 1, row.label));
        for h in &row.hours {
            s.push_str(&format!(" {h:.2} |"));
        }
        s.push('\n');
    }
    s
}

/// Table 5: execution time with the fault detected at X, comparing the
/// detection-only response against `k+1` rollback attempts.
#[derive(Debug, Clone)]
pub struct Table5 {
    pub x_percent: Vec<f64>,
    pub k_max: u32,
    /// `only_detection[i]` — hours for `x_percent[i]` (Equation 4).
    pub only_detection: Vec<f64>,
    /// `rollback[i][k]` — hours for k rollbacks at `x_percent[i]`, `None`
    /// where the checkpoint was not yet stored (NA).
    pub rollback: Vec<Vec<Option<f64>>>,
}

/// §4.4's admissibility: by progress fraction `x` of the detection-only
/// reference time (Equation 3), `floor(x·T_ref / t_i)` checkpoints have
/// been stored; rolling back `k+1` of them requires that many to exist.
pub fn admissible(p: &Params, x: f64, k: u32) -> bool {
    let t_ref = eq3_detect_fa(p);
    let stored = (x * t_ref / p.t_i).floor() as i64;
    (k as i64) < stored
}

/// Regenerate Table 5 for one parameter set (the paper uses Jacobi).
pub fn table5(p: &Params, xs: &[f64], k_max: u32) -> Table5 {
    const H: f64 = 3600.0;
    let only: Vec<f64> = xs.iter().map(|x| eq4_detect_fp(p, *x) / H).collect();
    let mut rollback = Vec::new();
    for &x in xs {
        let mut row = Vec::new();
        for k in 0..=k_max {
            row.push(if admissible(p, x, k) {
                Some(eq6_sys_fp(p, k) / H)
            } else {
                None
            });
        }
        rollback.push(row);
    }
    Table5 {
        x_percent: xs.iter().map(|x| x * 100.0).collect(),
        k_max,
        only_detection: only,
        rollback,
    }
}

/// Markdown rendering of Table 5.
pub fn table5_markdown(t: &Table5) -> String {
    let mut s = String::from("| X [%] | Only detection [hs] |");
    for k in 0..=t.k_max {
        s.push_str(&format!(" k={k} |"));
    }
    s.push_str("\n|---|---|");
    for _ in 0..=t.k_max {
        s.push_str("---|");
    }
    s.push('\n');
    for (i, x) in t.x_percent.iter().enumerate() {
        s.push_str(&format!("| {x:.0} | {:.2} |", t.only_detection[i]));
        for cell in &t.rollback[i] {
            match cell {
                Some(h) => s.push_str(&format!(" {h:.2} |")),
                None => s.push_str(" NA |"),
            }
        }
        s.push('\n');
    }
    s
}

/// §4.4 crossover: the progress fraction X at which the detection-only
/// response (Equation 4) costs the same as recovery with `k` extra
/// rollbacks (Equation 14). Below it, stop-and-relaunch wins; above it,
/// rolling back wins. Solved in closed form from the linearity of Eq. 4:
/// `X* = (Eq14(k) - Eq4(0)) / (T_prog (1 + f_d))`.
pub fn threshold_x(p: &Params, k: u32) -> f64 {
    let eq14 = eq6_sys_fp(p, k);
    let eq4_at_0 = eq4_detect_fp(p, 0.0);
    (eq14 - eq4_at_0) / (p.t_prog * (1.0 + p.f_d))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::PaperApp;

    #[test]
    fn table4_has_12_rows_and_3_columns() {
        let cols: Vec<(&str, Params)> = PaperApp::ALL
            .iter()
            .map(|a| (a.label(), a.paper_params()))
            .collect();
        let t = table4(&cols);
        assert_eq!(t.len(), 12);
        assert!(t.iter().all(|r| r.hours.len() == 3));
        let md = table4_markdown(&cols);
        assert!(md.contains("MATMUL"));
        assert!(md.contains("Eq. 8"));
    }

    #[test]
    fn table5_na_pattern_matches_paper() {
        // §4.4, Jacobi, t_i = 1 h: X=30% → k ≤ 1 admissible; X=50% → k ≤ 3;
        // X=80% → all of k ≤ 4.
        let p = PaperApp::Jacobi.paper_params();
        let t = table5(&p, &[0.3, 0.5, 0.8], 4);
        let admissible_count =
            |row: &Vec<Option<f64>>| row.iter().filter(|c| c.is_some()).count();
        assert_eq!(admissible_count(&t.rollback[0]), 2); // k=0,1
        assert_eq!(admissible_count(&t.rollback[1]), 4); // k=0..3
        assert_eq!(admissible_count(&t.rollback[2]), 5); // k=0..4
    }

    #[test]
    fn thresholds_bracket_decisions() {
        // For X below threshold_x(k=0), stop-and-relaunch beats k=0 rollback.
        let p = PaperApp::Jacobi.paper_params();
        let x0 = threshold_x(&p, 0);
        assert!(x0 > 0.0 && x0 < 0.2);
        let below = eq4_detect_fp(&p, x0 * 0.5);
        let above = eq4_detect_fp(&p, (x0 * 1.5).min(1.0));
        let k0 = eq6_sys_fp(&p, 0);
        assert!(below < k0);
        assert!(above > k0);
    }

    #[test]
    fn table5_markdown_prints_na() {
        let p = PaperApp::Jacobi.paper_params();
        let t = table5(&p, &[0.3], 4);
        let md = table5_markdown(&t);
        assert!(md.contains("NA"));
    }
}
