//! The replica thread's main loop: a phase-structured program driver.
//!
//! Applications are written as resumable phase sequences (see
//! [`crate::apps::spec::AppSpec`]); the driver walks the phases from the
//! context's start cursor (0 for a fresh run, `snapshot.cursor` after a
//! restart) and applies pending fault injections at phase boundaries — the
//! paper's "between X and Y" injection windows.

use crate::apps::spec::AppSpec;
use crate::error::Result;

use super::ReplicaCtx;

/// Run the application program on this replica from `ctx.cursor` to
/// completion. Unwinds with a fault-signal error on detection/abort.
pub fn replica_main(app: &dyn AppSpec, ctx: &mut ReplicaCtx) -> Result<()> {
    let n = app.n_phases();
    while ctx.cursor < n {
        let phase = ctx.cursor;
        // Injection window "… → phase": fires right before the phase runs.
        ctx.inject_before_phase(phase);
        app.run_phase(ctx, phase)?;
        ctx.cursor += 1;
    }
    Ok(())
}
