//! Dual-replica execution of one rank — the operational core of SEDAR.
//!
//! Every application rank runs as **two replica threads** executing the same
//! deterministic program over private [`VarStore`]s. All interaction with
//! the outside world goes through the [`ReplicaCtx`] operations defined
//! here, which implement the paper's detection protocol (§3.1):
//!
//! * [`ReplicaCtx::sedar_send`] — replicas rendezvous, the outgoing buffer
//!   contents are compared (full bytes or SHA-256 per config), and only the
//!   leading replica performs the actual network send;
//! * [`ReplicaCtx::sedar_recv`] — the leading replica receives, the sibling
//!   gets a copy before either resumes (and the rendezvous doubles as a TOE
//!   watchdog for the receiver side);
//! * [`ReplicaCtx::validate_result`] — final-result comparison (FSC);
//! * [`ReplicaCtx::checkpoint`] — strategy-dispatched: no-op, system-level
//!   chain store (§3.2), or validated user-level checkpoint (Algorithm 2).
//!
//! A divergence anywhere reports to the [`Detector`], which safe-stops the
//! whole run; the coordinator then drives recovery.
//!
//! Detection is **allocation-free on the send path**: store buffers are
//! shared ([`crate::util::bytes::SharedBuf`]), so the lead's payload clone
//! is a reference bump, full-contents comparison borrows both stores in
//! place, and the replica's comparison token crosses the rendezvous as a
//! shared view ([`TokenBuf::Shared`]) — see `benches/micro_hotpath.rs` and
//! `BENCH_pr3.json` for the measured effect.

pub mod driver;
pub mod pair;

use std::sync::Arc;
use std::time::Duration;

use crate::checkpoint::snapshot::Codec;
use crate::checkpoint::user::UserSnapshot;
use crate::checkpoint::{RankSnapshot, SystemChain, UserChain};
use crate::config::{CollectiveImpl, RunConfig, Strategy};
use crate::coordinator::trace::Trace;
use crate::detect::{buffers_equal, sha256, Detector, Token, ValidationMode};
use crate::error::{FaultClass, Result, SedarError};
use crate::inject::Injector;
use crate::metrics::{Phase, RunMetrics, ScopedTimer};
use crate::obs::EventKind;
use crate::runtime::EngineHandle;
use crate::state::{Buf, DType, Var, VarStore};
use crate::util::bytes::TokenBuf;
use crate::util::clock::Clock;
use crate::vmpi::Endpoint;

use pair::{PairError, PairSync};

/// Compact wire encoding of a [`Var`] for replica-to-replica copies.
pub fn encode_var(v: &Var) -> Vec<u8> {
    let bytes = v.buf.bytes();
    let mut out = Vec::with_capacity(16 + bytes.len());
    out.push(match v.buf.dtype() {
        DType::F32 => 0,
        DType::F64 => 1,
        DType::I64 => 2,
        DType::U8 => 3,
    });
    out.push(v.shape.len() as u8);
    for d in &v.shape {
        out.extend_from_slice(&(*d as u64).to_le_bytes());
    }
    out.extend_from_slice(bytes);
    out
}

/// Wire encoding of a native-gather result set: the root's leading replica
/// ships every gathered part to its sibling in one blob.
///
/// ```text
/// blob := n u32 | n × ( len u64 | encode_var bytes )
/// ```
pub fn encode_gather_parts(parts: &[Var]) -> Vec<u8> {
    let mut blob = Vec::with_capacity(4 + parts.len() * 32);
    blob.extend_from_slice(&(parts.len() as u32).to_le_bytes());
    for p in parts {
        let e = encode_var(p);
        blob.extend_from_slice(&(e.len() as u64).to_le_bytes());
        blob.extend_from_slice(&e);
    }
    blob
}

/// Inverse of [`encode_gather_parts`], with every read bounds-checked: a
/// torn or short blob (the sibling died mid-push, a corrupted token) must
/// surface as a [`SedarError`] that safe-stops this world — the historical
/// unchecked indexing panicked the follower thread, which took down the
/// whole campaign worker instead of failing one cell.
pub fn decode_gather_parts(blob: &[u8]) -> Result<Vec<Var>> {
    let truncated = |what: &str, off: usize| {
        SedarError::Vmpi(format!(
            "gather blob truncated at {what} (offset {off}, {} byte(s) total)",
            blob.len()
        ))
    };
    if blob.len() < 4 {
        return Err(truncated("part count", 0));
    }
    let n = u32::from_le_bytes(blob[0..4].try_into().unwrap()) as usize;
    // A gather never collects more parts than ranks; a corrupt count must
    // not drive a giant allocation. Each part costs ≥ 10 bytes on the wire
    // (8-byte length prefix + 2-byte minimum encode_var).
    if n > blob.len().saturating_sub(4) / 10 {
        return Err(SedarError::Vmpi(format!(
            "gather blob declares {n} part(s) but holds only {} byte(s)",
            blob.len()
        )));
    }
    let mut off = 4usize;
    let mut parts = Vec::with_capacity(n);
    for _ in 0..n {
        if blob.len() - off < 8 {
            return Err(truncated("part length", off));
        }
        let len = u64::from_le_bytes(blob[off..off + 8].try_into().unwrap()) as usize;
        off += 8;
        if blob.len() - off < len {
            return Err(truncated("part payload", off));
        }
        parts.push(decode_var(&blob[off..off + len])?);
        off += len;
    }
    if off != blob.len() {
        return Err(SedarError::Vmpi(format!(
            "gather blob has {} trailing byte(s) after the last part",
            blob.len() - off
        )));
    }
    Ok(parts)
}

/// Inverse of [`encode_var`], with the header cross-checked against the
/// body: a torn or bit-flipped encoding (a faultnet-corrupted delivery, a
/// sibling that died mid-push) must surface as a [`SedarError`], never a
/// panic and never a structurally inconsistent [`Var`] whose shape
/// promises more elements than its buffer holds.
pub fn decode_var(data: &[u8]) -> Result<Var> {
    if data.len() < 2 {
        return Err(SedarError::Vmpi("truncated var encoding".into()));
    }
    let dtype = match data[0] {
        0 => DType::F32,
        1 => DType::F64,
        2 => DType::I64,
        3 => DType::U8,
        t => return Err(SedarError::Vmpi(format!("bad dtype tag {t}"))),
    };
    let ndim = data[1] as usize;
    let mut off = 2;
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        if off + 8 > data.len() {
            return Err(SedarError::Vmpi("truncated var shape".into()));
        }
        shape.push(u64::from_le_bytes(data[off..off + 8].try_into().unwrap()) as usize);
        off += 8;
    }
    let elem = match dtype {
        DType::F32 => 4,
        DType::F64 => 8,
        DType::I64 => 8,
        DType::U8 => 1,
    };
    let want = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .and_then(|n| n.checked_mul(elem));
    let body = &data[off..];
    if want != Some(body.len()) {
        return Err(SedarError::Vmpi(format!(
            "var payload length mismatch: shape {shape:?} ({dtype:?}) needs \
             {want:?} byte(s), encoding carries {}",
            body.len()
        )));
    }
    let buf = Buf::from_bytes(dtype, body)?;
    Ok(Var { shape, buf })
}

/// Everything a replica thread needs to run its program.
pub struct ReplicaCtx {
    pub rank: usize,
    pub nranks: usize,
    /// 0 = leading thread (owns the network endpoint), 1 = replica.
    pub replica: usize,
    /// Phase about to run / running.
    pub cursor: u64,
    /// The application state of THIS replica.
    pub store: VarStore,
    pub cfg: Arc<RunConfig>,
    pair: Arc<PairSync>,
    ep: Endpoint,
    detector: Arc<Detector>,
    injector: Arc<Injector>,
    sys_chain: Option<Arc<SystemChain>>,
    user_chain: Option<Arc<UserChain>>,
    engine: Option<EngineHandle>,
    metrics: Arc<RunMetrics>,
    trace: Arc<Trace>,
    /// The world's clock: every timing span and injected delay is modeled
    /// time, so verdicts are load-independent under a virtual clock.
    clock: Clock,
    /// Names of this rank's significant variables (user-level checkpoints).
    significant: Vec<String>,
    /// Solo (baseline) mode: no replica sibling exists. All pair
    /// rendezvous, comparisons and checkpoints become no-ops; `replica`
    /// then identifies the *instance* (for injection targeting).
    solo: bool,
}

/// Construction parameters for a [`ReplicaCtx`] (assembled by the
/// coordinator for each attempt).
pub struct ReplicaParts {
    pub rank: usize,
    pub nranks: usize,
    pub replica: usize,
    pub start_cursor: u64,
    pub store: VarStore,
    pub cfg: Arc<RunConfig>,
    pub pair: Arc<PairSync>,
    pub ep: Endpoint,
    pub detector: Arc<Detector>,
    pub injector: Arc<Injector>,
    pub sys_chain: Option<Arc<SystemChain>>,
    pub user_chain: Option<Arc<UserChain>>,
    pub engine: Option<EngineHandle>,
    pub metrics: Arc<RunMetrics>,
    pub trace: Arc<Trace>,
    pub clock: Clock,
    pub significant: Vec<String>,
    pub solo: bool,
}

impl ReplicaCtx {
    pub fn new(p: ReplicaParts) -> ReplicaCtx {
        ReplicaCtx {
            rank: p.rank,
            nranks: p.nranks,
            replica: p.replica,
            cursor: p.start_cursor,
            store: p.store,
            cfg: p.cfg,
            pair: p.pair,
            ep: p.ep,
            detector: p.detector,
            injector: p.injector,
            sys_chain: p.sys_chain,
            user_chain: p.user_chain,
            engine: p.engine,
            metrics: p.metrics,
            trace: p.trace,
            clock: p.clock,
            significant: p.significant,
            solo: p.solo,
        }
    }

    pub fn is_lead(&self) -> bool {
        self.solo || self.replica == 0
    }

    pub fn is_solo(&self) -> bool {
        self.solo
    }

    pub fn trace(&self, msg: impl Into<String>) {
        self.trace.emit(self.rank, self.replica, msg);
    }

    /// [`Self::trace`] plus the typed [`crate::obs::Event`] (same text).
    pub fn event(&self, kind: EventKind, msg: impl Into<String>) {
        self.trace.event(self.rank, self.replica, kind, msg);
    }

    /// RAII tick span for `phase`, attributed to this rank/replica.
    fn span(&self, phase: Phase) -> ScopedTimer<'_> {
        self.metrics
            .span(phase, self.rank as u32, self.replica as u32)
    }

    pub fn metrics(&self) -> &RunMetrics {
        &self.metrics
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Sleep for `d` of modeled time (instant in wall terms under a virtual
    /// clock) — the injector's delay hook routes through here.
    pub fn sleep(&self, d: Duration) {
        self.clock.sleep(d);
    }

    // ------------------------------------------------------------ internals

    /// Rendezvous with the sibling, exchanging `token`. Converts a missing
    /// sibling into a TOE detection at `site`.
    fn pair_exchange(&self, token: TokenBuf, site: &str) -> Result<TokenBuf> {
        if self.solo {
            return Ok(token);
        }
        let r = {
            let _sync = self.span(Phase::Sync);
            self.pair
                .exchange(self.replica, token, self.cfg.toe_timeout)
        };
        self.metrics.add(&self.metrics.sync_events, 1);
        match r {
            Ok(tok) => Ok(tok),
            Err(PairError::Aborted) => Err(SedarError::Aborted),
            Err(PairError::Timeout) => {
                self.event(
                    EventKind::ToeExpired,
                    format!("TOE: sibling missed rendezvous at {site}"),
                );
                Err(self
                    .detector
                    .report(FaultClass::Toe, self.rank, site, self.cursor))
            }
        }
    }

    fn pop_from_sibling(&self, site: &str) -> Result<TokenBuf> {
        if self.solo {
            return Ok(vec![1].into());
        }
        let r = {
            let _sync = self.span(Phase::Sync);
            self.pair.pop_mine(self.replica, self.cfg.toe_timeout)
        };
        match r {
            Ok(tok) => Ok(tok),
            Err(PairError::Aborted) => Err(SedarError::Aborted),
            Err(PairError::Timeout) => {
                self.event(
                    EventKind::ToeExpired,
                    format!("TOE: sibling missed rendezvous at {site}"),
                );
                Err(self
                    .detector
                    .report(FaultClass::Toe, self.rank, site, self.cursor))
            }
        }
    }

    fn push_to_sibling(&self, token: TokenBuf) {
        if self.solo {
            return;
        }
        self.pair.push_to_peer(self.replica, token);
    }

    /// Compare this replica's buffer against the sibling's and classify a
    /// mismatch as `class` at `site`. Returns Ok(()) on agreement.
    ///
    /// Protocol (perf changes P3 + P7, EXPERIMENTS.md §Perf): in `Full`
    /// mode the transfer is one-way **and zero-copy** — the replica ships a
    /// shared view of its buffer ([`TokenBuf::Shared`]; a reference, not
    /// bytes), the leader compares it against its own buffer in place and
    /// ships back a 1-byte verdict. No payload bytes are copied or
    /// allocated anywhere on this path, while the rendezvous (and therefore
    /// TOE detection) is preserved in both directions. `Sha256` mode
    /// exchanges 32-byte digests symmetrically — the digest crosses the
    /// channel exactly once (the historical build-then-clone double
    /// allocation is gone).
    fn compare_with_sibling(&self, buf: &Buf, site: &str, class: FaultClass) -> Result<()> {
        self.compare_with_sibling_inner(buf.bytes(), Some(buf), site, class)
    }

    /// [`Self::compare_with_sibling`] for ad-hoc byte strings with no
    /// shared storage behind them (the Native-scatter concatenated
    /// payload): the lead still compares in place with zero copies; only
    /// the replica's token falls back to an owned copy.
    fn compare_bytes_with_sibling(
        &self,
        bytes: &[u8],
        site: &str,
        class: FaultClass,
    ) -> Result<()> {
        self.compare_with_sibling_inner(bytes, None, site, class)
    }

    fn compare_with_sibling_inner(
        &self,
        bytes: &[u8],
        shared: Option<&Buf>,
        site: &str,
        class: FaultClass,
    ) -> Result<()> {
        if self.solo {
            return Ok(());
        }
        let equal = match self.cfg.validation {
            ValidationMode::Full => {
                if self.is_lead() {
                    let peer = self.pop_from_sibling_site(site)?;
                    let eq = {
                        let _cmp = self.span(Phase::Compare);
                        buffers_equal(bytes, peer.as_bytes())
                    };
                    self.push_to_sibling(vec![eq as u8].into());
                    eq
                } else {
                    let token = match shared {
                        Some(buf) => TokenBuf::Shared(buf.share()),
                        None => TokenBuf::Owned(bytes.to_vec()),
                    };
                    self.push_to_sibling(token);
                    let verdict = self.pop_from_sibling_site(site)?;
                    verdict.as_bytes()[0] == 1
                }
            }
            ValidationMode::Sha256 => {
                let token = {
                    let _cmp = self.span(Phase::Compare);
                    Token::new(ValidationMode::Sha256, bytes)
                };
                let peer = self.pair_exchange(token.to_wire().into(), site)?;
                token.matches(peer.as_bytes())
            }
        };
        self.metrics.add(&self.metrics.compare_bytes, bytes.len() as u64);
        self.detector.note_comparison(bytes.len());
        if equal {
            Ok(())
        } else {
            self.event(
                EventKind::Detected,
                format!("{class} divergence detected at {site}"),
            );
            Err(self.detector.report(class, self.rank, site, self.cursor))
        }
    }

    /// `pop_from_sibling` with the TOE classification at `site` (alias kept
    /// for the compare protocol's readability).
    fn pop_from_sibling_site(&self, site: &str) -> Result<TokenBuf> {
        self.pop_from_sibling(site)
    }

    /// Classify a transport error from a lead-side network operation at
    /// `site`. The faultnet layer surfaces its perturbations as typed
    /// transport errors; here they become SEDAR detections:
    ///
    /// * [`SedarError::NetCorrupt`] (payload CRC mismatch on take) →
    ///   **TDC** — the paper's Transmitted Data Corruption, caught at the
    ///   receiver instead of the sender-side replica comparison;
    /// * a receive timeout while a fault layer is installed → **TOE** —
    ///   a dropped message's absence, observed within the modeled lapse.
    ///
    /// Anything else (abort, protocol errors, timeouts on clean networks)
    /// passes through untouched.
    fn classify_net_err(&self, e: SedarError, site: &str) -> SedarError {
        match e {
            SedarError::NetCorrupt { src, dst, tag, seq } => {
                self.event(
                    EventKind::Detected,
                    format!(
                        "TDC divergence detected at {site} (transport CRC: \
                         src={src} dst={dst} tag={tag} seq={seq})"
                    ),
                );
                self.detector
                    .report(FaultClass::Tdc, self.rank, site, self.cursor)
            }
            SedarError::Vmpi(msg)
                if msg.contains("recv timeout")
                    && self.ep.network().fault_layer().is_some() =>
            {
                self.event(EventKind::ToeExpired, format!("TOE: {msg} at {site}"));
                self.detector
                    .report(FaultClass::Toe, self.rank, site, self.cursor)
            }
            other => other,
        }
    }

    /// Run a lead-side network operation result through the transport
    /// fault classifier.
    fn net_op<T>(&self, r: Result<T>, site: &str) -> Result<T> {
        r.map_err(|e| self.classify_net_err(e, site))
    }

    // ----------------------------------------------------- point-to-point

    /// Validated send (§3.1): compare the outgoing contents between
    /// replicas; on agreement the leading replica sends one copy.
    ///
    /// Zero payload copies end to end: the lead's `clone` is a reference
    /// bump into the shared buffer it hands the network, the comparison
    /// borrows both stores in place, and the replica's token is a shared
    /// view (perf changes P6 + P7).
    pub fn sedar_send(&mut self, dst: usize, tag: u32, var: &str, site: &str) -> Result<()> {
        if self.is_lead() {
            let v = self.store.get(var)?.clone();
            self.compare_with_sibling(&v.buf, site, FaultClass::Tdc)?;
            self.ep.send(dst, tag, v)?;
        } else {
            let v = self.store.get(var)?;
            // Reborrow dance: compare takes &self, store borrow is
            // immutable — both coexist.
            self.compare_with_sibling(&v.buf, site, FaultClass::Tdc)?;
        }
        Ok(())
    }

    /// Validated send of an ad-hoc value (not a named store variable) —
    /// used for sub-slices like scatter chunks.
    pub fn sedar_send_value(
        &mut self,
        dst: usize,
        tag: u32,
        v: &Var,
        site: &str,
    ) -> Result<()> {
        self.compare_with_sibling(&v.buf, site, FaultClass::Tdc)?;
        if self.is_lead() {
            self.ep.send(dst, tag, v.clone())?;
        }
        Ok(())
    }

    /// Receive into `into`: the leading replica receives from the network
    /// and copies the contents to its sibling before either resumes (§3.1:
    /// "it makes a copy of the received contents"). The rendezvous also
    /// makes a late sibling visible as a TOE at `site`.
    pub fn sedar_recv(&mut self, src: usize, tag: u32, into: &str, site: &str) -> Result<Var> {
        let v = if self.is_lead() {
            let v = match self.ep.recv(src, tag) {
                Ok(v) => v,
                Err(SedarError::Aborted) => return Err(SedarError::Aborted),
                Err(e) => return Err(self.classify_net_err(e, site)),
            };
            // Hand the copy to the sibling, then wait for its check-in token
            // (the receiver-side synchronization of Figure 1).
            self.push_to_sibling(encode_var(&v).into());
            self.pop_from_sibling(site)?;
            v
        } else {
            self.push_to_sibling(vec![1].into()); // check-in token
            let bytes = self.pop_from_sibling(site)?;
            decode_var(bytes.as_bytes())?
        };
        self.store.insert(into, v.clone());
        Ok(v)
    }

    // ---------------------------------------------------------- collectives

    /// Broadcast `var` from `root` (stores into `var` on non-roots).
    pub fn bcast(&mut self, root: usize, var: &str, site: &str) -> Result<()> {
        match self.cfg.collectives {
            CollectiveImpl::PointToPoint => {
                if self.rank == root {
                    for r in 0..self.nranks {
                        if r != root {
                            self.sedar_send(r, tag_for(site, r), var, site)?;
                        }
                    }
                } else {
                    self.sedar_recv(root, tag_for(site, self.rank), var, site)?;
                }
            }
            CollectiveImpl::Native => {
                // Validate once (root's full buffer participates — §4.2:
                // "in collective communications, the sender process also
                // participates, ... the corrupted data gets transmitted and
                // hence it is validated").
                if self.rank == root {
                    let v = self.store.get(var)?.clone();
                    self.compare_with_sibling(&v.buf, site, FaultClass::Tdc)?;
                    if self.is_lead() {
                        self.net_op(self.ep.bcast(root, Some(v)), site)?;
                    }
                } else {
                    let v = if self.is_lead() {
                        let v = self.net_op(self.ep.bcast(root, None), site)?;
                        self.push_to_sibling(encode_var(&v).into());
                        self.pop_from_sibling(site)?;
                        v
                    } else {
                        self.push_to_sibling(vec![1].into());
                        decode_var(self.pop_from_sibling(site)?.as_bytes())?
                    };
                    self.store.insert(var, v);
                }
            }
        }
        Ok(())
    }

    /// Validate a scatter root's chunk list **before** any rank commits to
    /// the collective. A short (or long) list used to slip straight into
    /// the send loop: the unserved ranks then blocked forever inside
    /// [`Self::sedar_recv`] until the rendezvous lapse converted the hang
    /// into a bogus TOE verdict — and the native arm's `chunks[root]`
    /// indexing panicked outright when `chunks.len() <= root`. Failing up
    /// front (like [`Endpoint::scatter`] does one layer down) turns both
    /// into an ordinary error that safe-stops the world.
    fn expect_scatter_chunks(&self, chunks: Option<Vec<Var>>) -> Result<Vec<Var>> {
        let chunks =
            chunks.ok_or_else(|| SedarError::Vmpi("scatter root needs chunks".into()))?;
        if chunks.len() != self.nranks {
            return Err(SedarError::Vmpi(format!(
                "scatter root needs {} chunks (one per rank), got {}",
                self.nranks,
                chunks.len()
            )));
        }
        Ok(chunks)
    }

    /// Scatter row-chunks of root's `src_var` into each rank's `into`.
    /// `chunks` is produced by the caller on the root (it knows the
    /// decomposition); non-roots pass `None`.
    pub fn scatter(
        &mut self,
        root: usize,
        chunks: Option<Vec<Var>>,
        into: &str,
        site: &str,
    ) -> Result<()> {
        match self.cfg.collectives {
            CollectiveImpl::PointToPoint => {
                if self.rank == root {
                    let chunks = self.expect_scatter_chunks(chunks)?;
                    // Root's own chunk stays local — and therefore
                    // UNVALIDATED in p2p mode: this is what makes the FSC
                    // injection scenarios possible (§4.2).
                    for (r, chunk) in chunks.into_iter().enumerate() {
                        if r == root {
                            self.store.insert(into, chunk);
                        } else {
                            self.sedar_send_value(r, tag_for(site, r), &chunk, site)?;
                        }
                    }
                } else {
                    self.sedar_recv(root, tag_for(site, self.rank), into, site)?;
                }
            }
            CollectiveImpl::Native => {
                if self.rank == root {
                    let chunks = self.expect_scatter_chunks(chunks)?;
                    // Validate the WHOLE scatter payload, own chunk included.
                    let mut all = Vec::new();
                    for c in &chunks {
                        all.extend_from_slice(c.buf.bytes());
                    }
                    self.compare_bytes_with_sibling(&all, site, FaultClass::Tdc)?;
                    let own = chunks[root].clone();
                    if self.is_lead() {
                        self.net_op(self.ep.scatter(root, Some(chunks)), site)?;
                    }
                    self.store.insert(into, own);
                } else {
                    let v = if self.is_lead() {
                        let v = self.net_op(self.ep.scatter(root, None), site)?;
                        self.push_to_sibling(encode_var(&v).into());
                        self.pop_from_sibling(site)?;
                        v
                    } else {
                        self.push_to_sibling(vec![1].into());
                        decode_var(self.pop_from_sibling(site)?.as_bytes())?
                    };
                    self.store.insert(into, v);
                }
            }
        }
        Ok(())
    }

    /// Gather each rank's `var` to `root`; returns the rank-ordered chunks
    /// on the root, `None` elsewhere.
    pub fn gather(&mut self, root: usize, var: &str, site: &str) -> Result<Option<Vec<Var>>> {
        match self.cfg.collectives {
            CollectiveImpl::PointToPoint => {
                if self.rank == root {
                    let mut out = Vec::with_capacity(self.nranks);
                    for r in 0..self.nranks {
                        if r == root {
                            // Own contribution stays local and unvalidated
                            // in p2p mode (FSC window).
                            out.push(self.store.get(var)?.clone());
                        } else {
                            let v =
                                self.sedar_recv(r, tag_for(site, r), &gather_tmp(r), site)?;
                            self.store.remove(&gather_tmp(r));
                            out.push(v);
                        }
                    }
                    Ok(Some(out))
                } else {
                    self.sedar_send(root, tag_for(site, self.rank), var, site)?;
                    Ok(None)
                }
            }
            CollectiveImpl::Native => {
                // Every rank validates its contribution — root's included.
                let v = self.store.get(var)?.clone();
                self.compare_with_sibling(&v.buf, site, FaultClass::Tdc)?;
                if self.rank == root {
                    if self.is_lead() {
                        let parts = self.net_op(self.ep.gather(root, v), site)?.unwrap();
                        // Share the gathered parts with the sibling.
                        self.push_to_sibling(encode_gather_parts(&parts).into());
                        self.pop_from_sibling(site)?;
                        Ok(Some(parts))
                    } else {
                        self.push_to_sibling(vec![1].into());
                        let tok = self.pop_from_sibling(site)?;
                        let parts = decode_gather_parts(tok.as_bytes())?;
                        Ok(Some(parts))
                    }
                } else {
                    if self.is_lead() {
                        self.net_op(self.ep.gather(root, v), site)?;
                    }
                    Ok(None)
                }
            }
        }
    }

    /// A plain barrier across ranks (both replicas rendezvous, leaders run
    /// the network barrier).
    pub fn barrier(&mut self, site: &str) -> Result<()> {
        self.pair_exchange(vec![1].into(), site)?;
        if self.is_lead() {
            self.net_op(self.ep.barrier(0), site)?;
        }
        // Second rendezvous so the sibling does not run ahead of the global
        // barrier point.
        self.pair_exchange(vec![2].into(), site)?;
        Ok(())
    }

    // ----------------------------------------------------------- validation

    /// Final-result comparison (§3.1's "comparison of the final results"):
    /// catches FSC that never crossed a message. Apps call this on the rank
    /// that owns the result (the Master).
    pub fn validate_result(&mut self, var: &str, site: &str) -> Result<()> {
        let v = self.store.get(var)?.clone();
        self.compare_with_sibling(&v.buf, site, FaultClass::Fsc)?;
        self.event(
            EventKind::Validated,
            format!("{site}: final result replicas agree"),
        );
        Ok(())
    }

    // ---------------------------------------------------------- checkpoints

    /// Strategy-dispatched checkpoint call (the app's `SEDAR_Ckpt()`).
    pub fn checkpoint(&mut self, ck_no: u64, site: &str) -> Result<()> {
        match self.cfg.strategy {
            Strategy::Baseline | Strategy::DetectOnly => Ok(()),
            Strategy::SysCkpt => self.system_checkpoint(ck_no, site),
            Strategy::UserCkpt => self.user_checkpoint(ck_no, site),
        }
    }

    /// §3.2: coordinated, whole-state, UNVALIDATED checkpoint. Captures both
    /// replicas' stores as they are — including any latent corruption.
    fn system_checkpoint(&mut self, ck_no: u64, site: &str) -> Result<()> {
        let chain = Arc::clone(self.sys_chain.as_ref().ok_or_else(|| {
            SedarError::Checkpoint("system checkpoint without a chain".into())
        })?);
        let _ck = self.span(Phase::SysCkpt);
        // The snapshot resumes at the phase AFTER this checkpoint.
        let resume_cursor = self.cursor + 1;
        if self.is_lead() {
            // Receive the sibling's serialized store (the rendezvous also
            // catches a TOE at the checkpoint site). The payload is
            // assembled from the two serialized stores directly — no store
            // clone, no re-serialization (perf change P4).
            let peer_bytes = self.pop_from_sibling(site)?;
            let my_bytes = self.store.serialize();
            let payload =
                RankSnapshot::serialize_parts(resume_cursor, &my_bytes, peer_bytes.as_bytes());
            let payload_len = payload.len();
            // Coordinated: all leaders enter, write, then the master commits.
            self.net_op(self.ep.barrier(0), site)?;
            chain
                .write_payload(ck_no, self.rank, &payload)
                .map_err(|e| SedarError::Checkpoint(format!("ck{ck_no}: {e}")))?;
            self.net_op(self.ep.barrier(0), site)?;
            if self.rank == 0 {
                chain.commit(ck_no)?;
            }
            self.net_op(self.ep.barrier(0), site)?;
            self.metrics
                .add(&self.metrics.sys_ckpt_bytes, payload_len as u64);
            self.metrics.add(&self.metrics.sys_ckpts, 1);
            // Release the sibling.
            self.push_to_sibling(vec![1].into());
            if self.rank == 0 {
                self.event(
                    EventKind::CkptStored,
                    format!("{site}: system checkpoint #{ck_no} stored"),
                );
            }
        } else {
            self.push_to_sibling(self.store.serialize().into());
            // Wait for the leader to finish the coordinated store. Uses the
            // (long) checkpoint lapse, not the TOE lapse: disk writes are
            // legitimately slow.
            let r = {
                let _sync = self.span(Phase::Sync);
                self.pair.pop_mine(self.replica, self.cfg.ckpt_timeout)
            };
            match r {
                Ok(_) => {}
                Err(PairError::Aborted) => return Err(SedarError::Aborted),
                Err(PairError::Timeout) => {
                    return Err(self.detector.report(
                        FaultClass::Toe,
                        self.rank,
                        site,
                        self.cursor,
                    ))
                }
            }
        }
        Ok(())
    }

    /// §3.3 / Algorithm 2: both replicas dump significant variables, hashes
    /// are cross-compared, the checkpoint is kept only if valid (and then
    /// the previous one is discarded). A corrupted candidate triggers
    /// detection at the checkpoint site.
    fn user_checkpoint(&mut self, ck_no: u64, site: &str) -> Result<()> {
        let chain = Arc::clone(self.user_chain.as_ref().ok_or_else(|| {
            SedarError::Checkpoint("user checkpoint without a chain".into())
        })?);
        let _ck = self.span(Phase::UserCkpt);
        let sig: Vec<&str> = self.significant.iter().map(|s| s.as_str()).collect();
        // Serialize the significant variables once; hash and (on the lead)
        // store those bytes directly (perf change P5).
        let payload = UserSnapshot::serialize_parts(
            self.cursor + 1,
            &self.store.serialize_filtered(Some(&sig)),
        );
        // Single-pass candidate encode (perf change P8): the lead's one scan
        // over the payload yields the digest to cross-validate AND the
        // ready-to-store frame (body + CRC fused); the sibling — which never
        // writes — computes only the digest, exactly as before. Gated on a
        // cheap codec: the digest must reach the sibling's rendezvous within
        // `toe_timeout`, so only `Codec::Raw` (a memcpy-cost pass, symmetric
        // with the sibling's sha256) may encode up front. Compressing codecs
        // keep the historical order — encode only *after* the verdict, under
        // the long `ckpt_timeout`, and never for an invalid candidate.
        let fuse = self.is_lead() && chain.codec() == Codec::Raw;
        let (frame, digest) = if fuse {
            let (frame, digest) = chain.encode_valid(&payload);
            (Some(frame), digest)
        } else {
            (None, sha256(&payload))
        };
        self.detector.note_comparison(payload.len());

        // Hash cross-validation between replicas (Algorithm 2 lines 4–10).
        // The 32-byte digest crosses the channel exactly once.
        let peer_digest = self.pair_exchange(digest.to_vec().into(), site)?;
        let local_valid = buffers_equal(&digest, peer_digest.as_bytes());

        // Global verdict: every rank must have a valid candidate, because
        // the checkpoint set is only usable if coordinated-consistent.
        let global_valid = if self.is_lead() {
            let verdict = Var::f32(&[], vec![if local_valid { 1.0 } else { 0.0 }]);
            let g = self.net_op(self.ep.allreduce_sum_f32(0, verdict), site)?;
            let ok = g.buf.as_f32()?[0] as usize == self.nranks;
            self.push_to_sibling(vec![ok as u8].into());
            ok
        } else {
            self.pop_from_sibling(site)?.as_bytes()[0] == 1
        };

        if global_valid {
            if self.is_lead() {
                match &frame {
                    Some(f) => chain.write_valid_frame(ck_no, self.rank, f),
                    None => chain.write_valid_payload(ck_no, self.rank, &payload),
                }
                .map_err(|e| SedarError::Checkpoint(format!("uck{ck_no}: {e}")))?;
                self.net_op(self.ep.barrier(0), site)?;
                if self.rank == 0 {
                    chain.commit_valid(ck_no)?;
                    self.event(
                        EventKind::CkptStored,
                        format!("{site}: user checkpoint #{ck_no} VALID (previous discarded)"),
                    );
                }
                self.net_op(self.ep.barrier(0), site)?;
                self.push_to_sibling(vec![1].into());
                self.metrics
                    .add(&self.metrics.user_ckpt_bytes, payload.len() as u64);
                self.metrics.add(&self.metrics.user_ckpts, 1);
            } else {
                let r = self.pair.pop_mine(self.replica, self.cfg.ckpt_timeout);
                if matches!(r, Err(PairError::Aborted)) {
                    return Err(SedarError::Aborted);
                }
            }
            Ok(())
        } else {
            // Corrupted candidate: not stored; detection fires here (the
            // fault happened within the last checkpoint interval).
            self.event(
                EventKind::CkptCorrupt,
                format!("{site}: user checkpoint #{ck_no} CORRUPTED"),
            );
            Err(self
                .detector
                .report(FaultClass::CkptCorrupt, self.rank, site, self.cursor))
        }
    }

    // -------------------------------------------------------------- compute

    /// Run a compute kernel: the AOT XLA artifact when enabled, otherwise
    /// the caller's pure-rust fallback (bit-identical for our workloads).
    pub fn compute<F>(&self, artifact: &str, inputs: Vec<Var>, fallback: F) -> Result<Vec<Var>>
    where
        F: FnOnce(&[Var]) -> Result<Vec<Var>>,
    {
        let out = {
            let _exec = self.span(Phase::Exec);
            match (&self.engine, self.cfg.use_xla) {
                (Some(engine), true) => engine.execute(artifact, inputs),
                _ => fallback(&inputs),
            }
        };
        self.metrics.add(&self.metrics.execs, 1);
        out
    }

    // ------------------------------------------------------------ injection

    /// Driver hook: apply pending bit-flip injections for this phase.
    pub fn inject_before_phase(&mut self, phase: u64) {
        for rec in
            self.injector
                .maybe_inject_at_phase(phase, self.rank, self.replica, &mut self.store)
        {
            self.event(
                EventKind::Injected,
                format!("INJECTED [{}] {}", rec.name, rec.description),
            );
        }
    }

    /// Compute-loop hook: index-corruption (TOE) injection. Returns the
    /// number of sub-blocks to redo; the app re-runs them and this replica
    /// arrives late at the next rendezvous.
    pub fn maybe_index_rollback(&self, phase: u64, subblock: u64) -> Option<(u64, Duration)> {
        let r = self
            .injector
            .maybe_index_rollback(phase, subblock, self.rank, self.replica);
        if let Some((redo, delay)) = r {
            self.event(
                EventKind::Injected,
                format!(
                    "INJECTED index rollback at subblock {subblock}: redo {redo}, delay {delay:?}"
                ),
            );
        }
        r
    }
}

/// The [`tag_for`] formula's parameters, named once so the compile-time
/// bound below is derived from the SAME constants the formula uses: user
/// tags start above the small hand-assigned app tags (`TAG_USER_BASE`),
/// fold the site name into one of `TAG_SITE_BUCKETS` buckets, and reserve
/// `TAG_PEER_SLOTS` tags per bucket for the peer index.
const TAG_USER_BASE: u32 = 64;
const TAG_SITE_BUCKETS: u32 = 1000;
const TAG_PEER_SLOTS: u32 = 64;

/// Highest tag [`tag_for`] can produce. The compile-time proof below is
/// the tag-space guard: user-site tags must stay strictly under
/// [`crate::vmpi::collectives::COLLECTIVE_TAG_BASE`], or a new app's send
/// would silently alias a collective-internal tag like `TAG_BARRIER_IN`
/// and deadlock or cross-deliver. Because the bound and the formula share
/// the constants above, widening either parameter past the tag space
/// fails to compile; the `debug_assert` re-checks the invariant on every
/// generated tag in debug builds (belt and braces against a structural
/// formula edit).
const TAG_FOR_MAX: u32 =
    TAG_USER_BASE + (TAG_SITE_BUCKETS - 1) * TAG_PEER_SLOTS + (TAG_PEER_SLOTS - 1);
const _: () = assert!(
    TAG_FOR_MAX < crate::vmpi::collectives::COLLECTIVE_TAG_BASE,
    "user-site tag formula must stay below the collective tag space"
);

fn tag_for(site: &str, peer: usize) -> u32 {
    // User tags must stay below the collective tag space (1 << 16) and above
    // the small hand-assigned tags apps use (< 64); fold the site name in so
    // phases cannot alias.
    let mut h: u32 = 2166136261;
    for b in site.bytes() {
        h = (h ^ b as u32).wrapping_mul(16777619);
    }
    let tag = TAG_USER_BASE
        + (h % TAG_SITE_BUCKETS) * TAG_PEER_SLOTS
        + (peer as u32 % TAG_PEER_SLOTS);
    debug_assert!(
        tag < crate::vmpi::collectives::COLLECTIVE_TAG_BASE,
        "user-site tag {tag} for '{site}' aliases the collective tag space"
    );
    tag
}

fn gather_tmp(rank: usize) -> String {
    format!("__gather_tmp_{rank}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn var_encoding_roundtrip() {
        let v = Var::f32(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]);
        let e = encode_var(&v);
        let d = decode_var(&e).unwrap();
        assert_eq!(d, v);
    }

    #[test]
    fn var_encoding_scalar_i64() {
        let v = Var::i64_scalar(-99);
        assert_eq!(decode_var(&encode_var(&v)).unwrap(), v);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode_var(&[]).is_err());
        assert!(decode_var(&[9, 1, 2]).is_err());
    }

    #[test]
    fn malformed_var_encoding_is_an_error_never_a_panic() {
        let v = Var::f32(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let e = encode_var(&v);
        // Every strict prefix — mid-header, mid-shape, mid-payload, and the
        // element-boundary cuts a length-unaware decoder would accept as a
        // shorter-but-valid buffer under the original shape.
        for cut in 0..e.len() {
            assert!(
                decode_var(&e[..cut]).is_err(),
                "prefix of {cut} byte(s) decoded"
            );
        }
        // Trailing bytes after the declared payload are refused.
        let mut padded = e.clone();
        padded.extend_from_slice(&[0u8; 4]);
        assert!(decode_var(&padded).is_err());
        // A corrupted rank byte tears the header apart.
        let mut bent = e.clone();
        bent[1] = 7;
        assert!(decode_var(&bent).is_err());
        // A corrupted dimension no longer matches the body — and an absurd
        // one must not size an allocation (checked multiply, no overflow).
        let mut huge = e;
        huge[2..10].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_var(&huge).is_err());
    }

    #[test]
    fn tags_distinct_per_site() {
        assert_ne!(tag_for("SCATTER", 1), tag_for("GATHER", 1));
        assert_ne!(tag_for("SCATTER", 1), tag_for("SCATTER", 2));
        assert!(tag_for("BCAST", 63) < crate::vmpi::collectives::COLLECTIVE_TAG_BASE);
    }

    #[test]
    fn every_user_site_tag_stays_below_the_collective_space() {
        use crate::vmpi::collectives::COLLECTIVE_TAG_BASE;
        // Arbitrary site strings a new app could invent — including ones
        // chosen to push the FNV hash around — must never alias the
        // reserved collective tags, for any peer index.
        let sites = [
            "", "A", "SCATTER", "GATHER", "BCAST", "REDUCE", "VALIDATE", "HALO-EXCHANGE",
            "a-very-long-site-name-a-new-app-might-pick", "ünïcode-sité", "\u{10FFFF}",
        ];
        for site in sites {
            for peer in [0usize, 1, 63, 64, 65, 1000, usize::MAX] {
                let tag = tag_for(site, peer);
                assert!(
                    (64..COLLECTIVE_TAG_BASE).contains(&tag),
                    "site '{site}' peer {peer} produced tag {tag}"
                );
            }
        }
        assert!(TAG_FOR_MAX < COLLECTIVE_TAG_BASE);
    }

    #[test]
    fn gather_blob_roundtrip() {
        let parts = vec![
            Var::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]),
            Var::i64_scalar(-7),
            Var::f32(&[0], vec![]),
        ];
        let blob = encode_gather_parts(&parts);
        let back = decode_gather_parts(&blob).unwrap();
        assert_eq!(back, parts);
        assert!(decode_gather_parts(&encode_gather_parts(&[])).unwrap().is_empty());
    }

    #[test]
    fn malformed_gather_blob_errors_instead_of_panicking() {
        let parts = vec![
            Var::f32(&[2], vec![1.0, 2.0]),
            Var::f32(&[3], vec![4.0, 5.0, 6.0]),
        ];
        let blob = encode_gather_parts(&parts);
        // Every truncation point — including mid-count, mid-length and
        // mid-payload — must be a recoverable error, never a panic.
        for cut in 0..blob.len() {
            assert!(
                decode_gather_parts(&blob[..cut]).is_err(),
                "prefix of {cut} byte(s) decoded"
            );
        }
        // A count far beyond what the blob can hold is rejected before any
        // allocation is sized from it.
        let mut lying = blob.clone();
        lying[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_gather_parts(&lying).is_err());
        // Trailing garbage after the declared parts is refused too.
        let mut padded = blob.clone();
        padded.push(0xEE);
        assert!(decode_gather_parts(&padded).is_err());
        // A part length pointing past the end is caught.
        let mut overrun = blob;
        overrun[4..12].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_gather_parts(&overrun).is_err());
    }
}
