//! Intra-rank replica rendezvous.
//!
//! The two replicas of a rank synchronize at every communication,
//! checkpoint and validation event (§3.1: "the leading thread stops running
//! and then waits for its replica to reach the same point"). [`PairSync`]
//! implements the rendezvous as a pair of FIFO cells — replica *r* pushes
//! its comparison token into its sibling's cell and pops its own. FIFO
//! ordering keeps successive rendezvous rounds aligned without a generation
//! counter, because both replicas execute the *same deterministic sequence*
//! of SEDAR operations.
//!
//! The pop carries the **TOE lapse**: if the sibling does not check in
//! within the configured timeout, the waiting replica reports a Time-Out
//! Error (§3.1: "if an appreciable delay is noticed between the two
//! replicas, it is considered that a silent error has caused the separation
//! of their flows"). The lapse is modeled time on the world's
//! [`Clock`] — real milliseconds under a wall clock, logical ticks under a
//! virtual one, where a TOE fires the instant the world quiesces.
//!
//! Tokens are [`TokenBuf`]s: small control blobs stay owned vectors, while
//! full-payload comparison tokens cross as zero-copy
//! [`crate::util::bytes::SharedBuf`] views — the channel moves a reference,
//! never the message bytes.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::clock::{Clock, Wait, WaitPoint};

pub use crate::util::bytes::TokenBuf;

/// Why a rendezvous pop failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PairError {
    /// The sibling did not arrive within the lapse — a TOE.
    Timeout,
    /// The run was safe-stopped by a detection elsewhere.
    Aborted,
}

struct Cell {
    q: Mutex<VecDeque<TokenBuf>>,
    /// Queue depth mirror — lets the consumer spin without touching the
    /// mutex (no contention with the producer).
    depth: AtomicUsize,
    /// This cell's wakeup channel: the sibling's push notifies it, the
    /// owning replica's pop parks on it (targeted under a wall clock, an
    /// alias for the world clock under a virtual one).
    wp: WaitPoint,
}

impl Cell {
    fn new(wp: WaitPoint) -> Cell {
        Cell {
            q: Mutex::new(VecDeque::new()),
            depth: AtomicUsize::new(0),
            wp,
        }
    }
}

/// Rendezvous + token-exchange channel between the two replicas of a rank.
pub struct PairSync {
    /// `cells[r]` holds tokens destined *for* replica `r`.
    cells: [Cell; 2],
    abort: Arc<AtomicBool>,
    clock: Clock,
}

/// Spin iterations before parking in [`PairSync::pop_mine`]. Adaptive:
/// spinning is only profitable when the sibling replica can actually run
/// concurrently — on a single-core host it *starves* the sibling (measured
/// 3.3 µs → 30 µs per rendezvous; EXPERIMENTS.md §Perf, change P2), so we
/// park immediately there. Virtual-clock worlds never spin: a waiter must
/// count as blocked for quiescence detection to see the world as idle.
fn spin_rounds() -> u32 {
    use std::sync::OnceLock;
    static ROUNDS: OnceLock<u32> = OnceLock::new();
    *ROUNDS.get_or_init(|| {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        if cores > 1 {
            1500
        } else {
            0
        }
    })
}

impl PairSync {
    /// Wall-clock pair (interactive/test default).
    pub fn new(abort: Arc<AtomicBool>) -> Arc<PairSync> {
        Self::with_clock(abort, Clock::wall())
    }

    /// Pair whose rendezvous waits route through `clock` — the coordinator
    /// passes the per-world clock so detector aborts (which notify the same
    /// clock via the network) wake pair waiters too.
    pub fn with_clock(abort: Arc<AtomicBool>, clock: Clock) -> Arc<PairSync> {
        let cells = [
            Cell::new(clock.wait_point()),
            Cell::new(clock.wait_point()),
        ];
        Arc::new(PairSync {
            cells,
            abort,
            clock,
        })
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    fn is_aborted(&self) -> bool {
        self.abort.load(Ordering::SeqCst)
    }

    /// Deposit a token for the *other* replica. Never blocks.
    pub fn push_to_peer(&self, me: usize, token: TokenBuf) {
        debug_assert!(me < 2);
        let cell = &self.cells[1 - me];
        {
            let mut q = cell.q.lock().unwrap();
            q.push_back(token);
            cell.depth.store(q.len(), Ordering::Release);
        }
        cell.wp.notify();
    }

    /// Take the next token destined for me, waiting up to `lapse` of
    /// modeled time.
    ///
    /// Fast path (wall clocks only): lockstep replicas arrive at rendezvous
    /// within microseconds of each other, so we spin briefly before parking
    /// — saves the futex round trip on the detection hot path
    /// (EXPERIMENTS.md §Perf, change P2).
    pub fn pop_mine(&self, me: usize, lapse: Duration) -> Result<TokenBuf, PairError> {
        debug_assert!(me < 2);
        let cell = &self.cells[me];
        if !self.clock.is_virtual() {
            // Spin phase: watch the lock-free depth mirror; only touch the
            // mutex once a token is visible (no producer contention).
            let mut spins = 0u32;
            let max_spins = spin_rounds();
            while spins < max_spins {
                if cell.depth.load(Ordering::Acquire) > 0 {
                    break;
                }
                if self.is_aborted() {
                    return Err(PairError::Aborted);
                }
                std::hint::spin_loop();
                spins += 1;
            }
        }
        // Park phase (or immediate pop after a successful spin).
        let deadline = self.clock.deadline_after(lapse);
        loop {
            let gen = cell.wp.subscribe();
            if let Some(tok) = self.try_pop(cell)? {
                return Ok(tok);
            }
            match cell.wp.wait(gen, Some(deadline)) {
                Wait::Notified => continue,
                Wait::TimedOut => {
                    // The lapse and the sibling's push can race; prefer the
                    // token, exactly like a just-in-time arrival.
                    match self.try_pop(cell)? {
                        Some(tok) => return Ok(tok),
                        None => return Err(PairError::Timeout),
                    }
                }
                // A poisoned world cannot rendezvous again; unwind like a
                // safe-stop so the replica thread exits promptly.
                Wait::Poisoned => return Err(PairError::Aborted),
            }
        }
    }

    fn try_pop(&self, cell: &Cell) -> Result<Option<TokenBuf>, PairError> {
        let mut q = cell.q.lock().unwrap();
        if self.is_aborted() {
            return Err(PairError::Aborted);
        }
        let tok = q.pop_front();
        if tok.is_some() {
            cell.depth.store(q.len(), Ordering::Release);
        }
        Ok(tok)
    }

    /// Symmetric rendezvous: deposit my token, take the sibling's.
    pub fn exchange(
        &self,
        me: usize,
        token: TokenBuf,
        lapse: Duration,
    ) -> Result<TokenBuf, PairError> {
        self.push_to_peer(me, token);
        self.pop_mine(me, lapse)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> (Arc<PairSync>, Arc<AtomicBool>) {
        let abort = Arc::new(AtomicBool::new(false));
        (PairSync::new(Arc::clone(&abort)), abort)
    }

    #[test]
    fn exchange_swaps_tokens() {
        let (p, _) = pair();
        let p2 = Arc::clone(&p);
        let h = std::thread::spawn(move || {
            p2.exchange(1, b"from-1".to_vec().into(), Duration::from_secs(1))
                .unwrap()
        });
        let got0 = p
            .exchange(0, b"from-0".to_vec().into(), Duration::from_secs(1))
            .unwrap();
        let got1 = h.join().unwrap();
        assert_eq!(got0.as_bytes(), b"from-1");
        assert_eq!(got1.as_bytes(), b"from-0");
    }

    #[test]
    fn fifo_keeps_rounds_aligned() {
        let (p, _) = pair();
        let p2 = Arc::clone(&p);
        let h = std::thread::spawn(move || {
            for i in 0..20u8 {
                let got = p2
                    .exchange(1, vec![100 + i].into(), Duration::from_secs(1))
                    .unwrap();
                assert_eq!(got.as_bytes(), &[i]);
            }
        });
        for i in 0..20u8 {
            let got = p
                .exchange(0, vec![i].into(), Duration::from_secs(1))
                .unwrap();
            assert_eq!(got.as_bytes(), &[100 + i]);
        }
        h.join().unwrap();
    }

    #[test]
    fn missing_sibling_times_out() {
        let (p, _) = pair();
        let err = p.pop_mine(0, Duration::from_millis(50)).unwrap_err();
        assert_eq!(err, PairError::Timeout);
    }

    #[test]
    fn missing_sibling_times_out_instantly_under_virtual_clock() {
        let clock = Clock::virtual_clock();
        clock.join_n(1);
        let _g = clock.guard();
        let abort = Arc::new(AtomicBool::new(false));
        let p = PairSync::with_clock(abort, clock.clone());
        // A 10-minute TOE lapse costs zero wall time in an idle world.
        let err = p.pop_mine(0, Duration::from_secs(600)).unwrap_err();
        assert_eq!(err, PairError::Timeout);
        assert!(clock.now() >= Clock::ticks(Duration::from_secs(600)));
    }

    #[test]
    fn abort_interrupts_wait() {
        // Either interleaving passes: abort-before-pop fails fast, pop-
        // before-abort is woken by the clock notification that production
        // aborts issue (Network::abort notifies the shared world clock).
        let (p, abort) = pair();
        let p2 = Arc::clone(&p);
        let h = std::thread::spawn(move || {
            abort.store(true, Ordering::SeqCst);
            p2.clock().notify();
        });
        let err = p.pop_mine(0, Duration::from_secs(10)).unwrap_err();
        assert_eq!(err, PairError::Aborted);
        h.join().unwrap();
    }

    #[test]
    fn asymmetric_push_pop() {
        let (p, _) = pair();
        p.push_to_peer(0, b"copy".to_vec().into()); // replica 0 → replica 1
        let got = p.pop_mine(1, Duration::from_millis(100)).unwrap();
        assert_eq!(got.as_bytes(), b"copy");
    }

    #[test]
    fn shared_token_crosses_without_copying() {
        use crate::util::bytes::SharedBuf;
        let (p, _) = pair();
        let payload = SharedBuf::from_bytes(&[7u8; 1024]);
        p.push_to_peer(0, payload.clone().into());
        let got = p.pop_mine(1, Duration::from_millis(100)).unwrap();
        match &got {
            TokenBuf::Shared(s) => {
                assert!(SharedBuf::ptr_eq(s, &payload), "token must share the allocation")
            }
            TokenBuf::Owned(_) => panic!("shared token arrived as an owned copy"),
        }
        assert_eq!(got.as_bytes(), &[7u8; 1024][..]);
    }
}
