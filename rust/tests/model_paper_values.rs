//! The analytical model vs the paper's published numbers.
//!
//! Feeding the Table-3 parameters into Equations 1–14 must regenerate
//! Table 4 and Table 5 of the paper to rounding tolerance (±0.02 h — the
//! paper prints 2 decimals of hours computed from unrounded measurements),
//! and the §4.4 thresholds to < 1 percentage point.

use sedar::model::equations::*;
use sedar::model::params::PaperApp;
use sedar::model::tables::{table4, table5, threshold_x};

const H: f64 = 3600.0;
const TOL: f64 = 0.02; // hours

fn check(label: &str, got_h: f64, want_h: f64) {
    assert!(
        (got_h - want_h).abs() <= TOL,
        "{label}: got {got_h:.3} h, paper says {want_h:.2} h"
    );
}

/// The paper's Table 4, verbatim (hours).
const PAPER_TABLE4: [(&str, [f64; 3]); 12] = [
    ("baseline fa", [10.22, 8.92, 11.15]),
    ("baseline fp", [20.45, 17.85, 22.35]),
    ("detect fa", [10.23, 8.97, 11.16]),
    ("detect fp x=30", [13.29, 11.67, 14.50]),
    ("detect fp x=50", [15.33, 13.46, 16.73]),
    ("detect fp x=80", [18.39, 16.16, 20.08]),
    ("sys fa", [10.26, 9.00, 11.17]),
    ("sys fp k=0", [10.77, 9.50, 11.66]),
    ("sys fp k=1", [12.27, 11.01, 13.17]),
    ("sys fp k=4", [22.79, 21.53, 23.67]),
    ("user fa", [10.37, 8.99, 11.16]),
    ("user fp", [10.87, 9.50, 11.66]),
];

#[test]
fn table4_reproduces_paper_values() {
    let cols: Vec<(&str, sedar::model::Params)> = PaperApp::ALL
        .iter()
        .map(|a| (a.label(), a.paper_params()))
        .collect();
    let rows = table4(&cols);
    assert_eq!(rows.len(), PAPER_TABLE4.len());
    for (row, (label, want)) in rows.iter().zip(PAPER_TABLE4.iter()) {
        for (col, (got, want)) in row.hours.iter().zip(want.iter()).enumerate() {
            // The paper's own rounding wobbles by one hundredth in a few
            // cells (values computed from unrounded measurements); the
            // published SW baseline-fp cell (22.35) disagrees with its own
            // Equation 2 inputs by 0.05 h — tolerate 0.06 there.
            let tol = if *label == "baseline fp" { 0.06 } else { TOL };
            assert!(
                (got - want).abs() <= tol,
                "Table4 '{label}' col {col}: got {got:.3}, paper {want:.2}"
            );
        }
    }
}

#[test]
fn table5_reproduces_paper_values_and_na_cells() {
    let p = PaperApp::Jacobi.paper_params();
    let t = table5(&p, &[0.3, 0.5, 0.8], 4);

    // Only-detection column (Equation 4): 11.66 / 13.46 / 16.16.
    check("t5 only-det x=30", t.only_detection[0], 11.66);
    check("t5 only-det x=50", t.only_detection[1], 13.46);
    check("t5 only-det x=80", t.only_detection[2], 16.16);

    // Rollback columns (Equation 14): 9.50, 11.01, 13.52, 17.02, 21.53 —
    // independent of X where admissible.
    let want = [9.50, 11.01, 13.52, 17.02, 21.53];
    for (k, want) in want.iter().enumerate() {
        // X = 80 %: everything admissible.
        let got = t.rollback[2][k].expect("admissible at x=80");
        check(&format!("t5 k={k}"), got, *want);
    }
    // NA pattern: X=30 % admits k ≤ 1; X=50 % admits k ≤ 3.
    assert!(t.rollback[0][0].is_some() && t.rollback[0][1].is_some());
    assert!(t.rollback[0][2].is_none() && t.rollback[0][4].is_none());
    assert!(t.rollback[1][3].is_some() && t.rollback[1][4].is_none());
}

#[test]
fn section_4_4_thresholds() {
    // "X ≤ 5.88 %", "X ≥ 22.67 %", "X ≥ 50.61 %" for the Jacobi parameters.
    let p = PaperApp::Jacobi.paper_params();
    let x0 = threshold_x(&p, 0) * 100.0;
    let x1 = threshold_x(&p, 1) * 100.0;
    let x2 = threshold_x(&p, 2) * 100.0;
    assert!((x0 - 5.88).abs() < 1.0, "k=0 crossover: {x0:.2}% vs 5.88%");
    assert!((x1 - 22.67).abs() < 1.0, "k=1 crossover: {x1:.2}% vs 22.67%");
    assert!((x2 - 50.61).abs() < 1.0, "k=2 crossover: {x2:.2}% vs 50.61%");
    // And §4.4's qualitative reading holds exactly:
    // below x0 stop-and-relaunch wins over k=0 rollback.
    assert!(eq4_detect_fp(&p, x0 / 100.0 * 0.9) < eq6_sys_fp(&p, 0));
    assert!(eq4_detect_fp(&p, x0 / 100.0 * 1.1) > eq6_sys_fp(&p, 0));
}

#[test]
fn table4_qualitative_claims() {
    // §4.3's prose, checked as inequalities over the model:
    for app in PaperApp::ALL {
        let p = app.paper_params();
        // "the detection mechanism performs better than the baseline for
        //  all the applications, regardless of the time of detection"
        for x in [0.3, 0.5, 0.8] {
            assert!(eq4_detect_fp(&p, x) < eq2_baseline_fp(&p), "{}", app.label());
        }
        // "as long as the number of rollbacks is greater than 4, the time
        //  spent in reworking is longer than the baseline strategy"
        assert!(eq6_sys_fp(&p, 4) > eq2_baseline_fp(&p), "{}", app.label());
        assert!(eq6_sys_fp(&p, 1) < eq2_baseline_fp(&p), "{}", app.label());
        // "recovery from the last valid application-level checkpoint is
        //  almost equal to recovery from the last system-level checkpoint"
        assert!((eq8_user_fp(&p) - eq6_sys_fp(&p, 0)).abs() / H < 0.15, "{}", app.label());
    }
}

#[test]
fn aet_orders_strategies_at_high_fault_rates() {
    // At MTBE ≈ job length, checkpointing strategies must beat both the
    // baseline and detection-only on average execution time.
    let p = PaperApp::Jacobi.paper_params();
    let mtbe = p.t_prog; // one expected fault per run
    let aet_base = sedar::model::aet(eq1_baseline_fa(&p), eq2_baseline_fp(&p), p.t_prog, mtbe);
    let aet_det = sedar::model::aet(eq3_detect_fa(&p), eq4_detect_fp(&p, 0.5), p.t_prog, mtbe);
    let aet_sys = sedar::model::aet(eq5_sys_fa(&p), eq6_sys_fp(&p, 0), p.t_prog, mtbe);
    let aet_user = sedar::model::aet(eq7_user_fa(&p), eq8_user_fp(&p), p.t_prog, mtbe);
    assert!(aet_sys < aet_det && aet_sys < aet_base);
    assert!(aet_user < aet_det && aet_user < aet_base);
    // And detection-only still beats the blind baseline.
    assert!(aet_det < aet_base);
}
