//! Detection latency vs. the SPMD communication pattern — the Jacobi
//! latency workfault (paper §5 future-work item, mechanized; see
//! `sedar::workfault::jacobi`). 30 scenarios sweeping injection depth from
//! the exchanged block edges: detection must occur at exactly the
//! predicted halo exchange (latency = stencil distance), or at
//! GATHER/VALIDATE when the loop ends first, with the predicted rollback
//! counts.

use sedar::apps::jacobi::JacobiApp;
use sedar::config::RunConfig;
use sedar::workfault::jacobi as jl;

#[test]
fn latency_catalog_behaves_as_predicted() {
    let app = JacobiApp::new(64, 4, 12, 4);
    let cfg = RunConfig::for_tests("jacobi-latency");
    let mut failures = Vec::new();
    let mut latencies = Vec::new();
    for sc in jl::catalog(&app) {
        let (outcome, mismatches) = jl::run_scenario(&app, &sc, &cfg).unwrap();
        if !mismatches.is_empty() {
            failures.push(format!(
                "inject_iter={} rank={} row={}: {:?}",
                sc.inject_iter, sc.rank, sc.row, mismatches
            ));
        }
        if let jl::JDetect::Iter(i) = sc.detect {
            latencies.push((sc.latency_iters, i - sc.inject_iter));
            assert!(outcome.completed);
        }
    }
    assert!(
        failures.is_empty(),
        "{} latency scenario(s) diverged:\n{}",
        failures.len(),
        failures.join("\n")
    );
    // The headline relationship: observed latency == stencil distance for
    // every in-loop detection.
    for (predicted_d, observed_d) in latencies {
        assert_eq!(predicted_d, observed_d);
    }
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
}
