//! The campaign engine's determinism contract:
//!
//! 1. same `--seed` twice ⇒ byte-identical aggregated report;
//! 2. different shard counts (`--jobs 1` vs `--jobs 4`) ⇒ identical merged
//!    results;
//! 3. per-task seeds are pure functions of (campaign seed, scenario, app,
//!    strategy) — no wall-clock in any decision path.
//!
//! The sweeps here are filtered cells of the full 64 × 3 × 3 product so the
//! suite stays fast; the full sweep is the `sedar campaign` CLI gate.

use sedar::campaign::{run_campaign, CampaignSpec};
use sedar::config::RunConfig;

/// A small but representative slice: one TDC, one LE and one FSC scenario
/// (ids 2, 29, 50 — the rows the paper details in Table 2) across every
/// app, every strategy and both collective implementations (54 cells).
fn small_spec(tag: &str, jobs: usize) -> CampaignSpec {
    let mut spec = CampaignSpec::new(42);
    spec.apply_filter("scenario=2,scenario=29,scenario=50")
        .unwrap();
    spec.jobs = jobs;
    let toe_timeout = spec.base.toe_timeout;
    let mut base = RunConfig::for_tests(tag);
    base.run_dir = std::env::temp_dir().join(format!(
        "sedar-campdet-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    // Keep the campaign's generous rendezvous lapse: a loaded pool must
    // never turn a descheduled-but-healthy sibling into a spurious TOE
    // (that would break the jobs-invariance these tests assert).
    base.toe_timeout = toe_timeout;
    spec.base = base;
    spec
}

#[test]
fn same_seed_twice_is_byte_identical() {
    let spec_a = small_spec("rerun-a", 2);
    let spec_b = small_spec("rerun-b", 2);
    let a = run_campaign(&spec_a).unwrap();
    let b = run_campaign(&spec_b).unwrap();
    assert_eq!(a.outcomes.len(), 3 * 3 * 3 * 2);
    assert_eq!(
        a.deterministic_report(),
        b.deterministic_report(),
        "two sweeps with the same seed must render byte-identical reports"
    );
    // The representative slice must also actually pass the oracle.
    assert!(a.verdict(), "campaign failures:\n{}", a.deterministic_report());
    let _ = std::fs::remove_dir_all(&spec_a.base.run_dir);
    let _ = std::fs::remove_dir_all(&spec_b.base.run_dir);
}

#[test]
fn jobs_count_does_not_change_the_merged_result() {
    let spec_serial = small_spec("jobs1", 1);
    let spec_wide = small_spec("jobs4", 4);
    let serial = run_campaign(&spec_serial).unwrap();
    let wide = run_campaign(&spec_wide).unwrap();
    assert_eq!(
        serial.deterministic_report(),
        wide.deterministic_report(),
        "--jobs must not change the merged campaign result"
    );
    // Spot-check the order invariant at the outcome level too.
    for (s, w) in serial.outcomes.iter().zip(&wide.outcomes) {
        assert_eq!(s.index, w.index);
        assert_eq!(s.pass, w.pass);
        assert_eq!(s.restarts, w.restarts);
        assert_eq!(s.first_detection, w.first_detection);
    }
    let _ = std::fs::remove_dir_all(&spec_serial.base.run_dir);
    let _ = std::fs::remove_dir_all(&spec_wide.base.run_dir);
}

#[test]
fn different_seeds_change_task_seeds_but_not_the_verdict_shape() {
    // A different campaign seed reshuffles workloads and transplanted
    // injection sites, but the report structure (task list, columns) is
    // the same shape and the slice still passes.
    let mut spec = small_spec("seed7", 2);
    spec.seed = 7;
    let r = run_campaign(&spec).unwrap();
    assert_eq!(r.outcomes.len(), 54);
    assert!(r.verdict(), "campaign failures:\n{}", r.deterministic_report());
    let _ = std::fs::remove_dir_all(&spec.base.run_dir);
}
