//! Beyond-paper campaign axes (ROADMAP: "campaign coverage beyond the
//! paper"): `validation=sha256` and multi-fault cells run through the same
//! engine, the same filter machinery and the same deterministic report —
//! so fleet sweeps can cover more scenarios than Table 2.

use sedar::campaign::{build_tasks, run_campaign, CampaignSpec};
use sedar::config::RunConfig;
use sedar::detect::ValidationMode;

fn spec(tag: &str, filter: &str) -> CampaignSpec {
    let mut spec = CampaignSpec::new(11);
    spec.apply_filter(filter).unwrap();
    spec.jobs = 2;
    let toe_timeout = spec.base.toe_timeout;
    let mut base = RunConfig::for_tests(tag);
    base.run_dir = std::env::temp_dir().join(format!(
        "sedar-axes-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    base.toe_timeout = toe_timeout;
    spec.base = base;
    spec
}

#[test]
fn sha256_validation_cells_pass_end_to_end() {
    // One TDC scenario, every app × strategy × collectives mode, under
    // digest validation.
    let spec = spec("sha", "scenario=2,validation=sha256");
    let tasks = build_tasks(&spec);
    assert_eq!(tasks.len(), 18);
    assert!(tasks.iter().all(|t| t.validation == ValidationMode::Sha256));
    let report = run_campaign(&spec).unwrap();
    assert!(
        report.verdict(),
        "sha256 cells diverged:\n{}",
        report.deterministic_report()
    );
    // The axis is visible in the rendered rows.
    assert!(report.deterministic_report().contains("sha256"));
    let _ = std::fs::remove_dir_all(&spec.base.run_dir);
}

#[test]
fn multi_fault_cells_recover_and_stay_correct() {
    // Two armed faults per cell, matmul only (the jacobi/sw transplants
    // already run under their own seeds in the main determinism suite).
    let spec = spec("mf", "scenario=2,app=matmul,faults=2");
    let tasks = build_tasks(&spec);
    assert_eq!(tasks.len(), 6);
    assert!(tasks.iter().all(|t| t.faults == 2));
    let report = run_campaign(&spec).unwrap();
    assert!(
        report.verdict(),
        "multi-fault cells diverged:\n{}",
        report.deterministic_report()
    );
    let _ = std::fs::remove_dir_all(&spec.base.run_dir);
}

#[test]
fn widened_axes_multiply_cells_and_stay_deterministic() {
    // Both axes at once, narrowed to one app × strategy × collectives
    // mode to stay fast: 1 scenario × 2 validations × 2 fault counts = 4
    // cells.
    let filter = "scenario=2,app=matmul,strategy=sys,collectives=p2p,\
                  validation=full,validation=sha256,faults=1,faults=2";
    let spec_a = spec("wide-a", filter);
    let spec_b = spec("wide-b", filter);
    assert_eq!(build_tasks(&spec_a).len(), 4);
    let a = run_campaign(&spec_a).unwrap();
    let b = run_campaign(&spec_b).unwrap();
    assert_eq!(
        a.deterministic_report(),
        b.deterministic_report(),
        "widened sweeps must stay byte-deterministic"
    );
    assert!(a.verdict(), "failures:\n{}", a.deterministic_report());
    let _ = std::fs::remove_dir_all(&spec_a.base.run_dir);
    let _ = std::fs::remove_dir_all(&spec_b.base.run_dir);
}
