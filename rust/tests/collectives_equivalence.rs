//! The p2p-vs-native equivalence suite (§4.2).
//!
//! The paper's claim has two halves, and this suite mechanizes both:
//!
//! 1. **Equal coverage** where the corrupted datum is transmitted (TDC),
//!    never used (LE) or desynchronizes the replicas (TOE): the same
//!    scenario under the same seed must behave *identically* in both
//!    collective implementations — same detection class and site, same
//!    rollback count, and a **bit-identical final store**.
//! 2. **Strictly better coverage** where the corruption is root-local: the
//!    FSC scenarios whose data feeds a scatter/gather root contribution
//!    flip from "undetected until the final-result comparison" (p2p) to
//!    "detected at the collective itself" (native), with the shorter
//!    rollback `predict_native` derives.
//!
//! A third regression pins the scatter deadlock fix: a root handing the
//! collective a short chunk list must fail fast with an error — not strand
//! the unserved ranks in `sedar_recv` until the rendezvous lapse mints a
//! bogus TOE verdict (p2p), nor panic on `chunks[root]` (native).

use std::sync::Arc;

use sedar::apps::matmul::MatmulApp;
use sedar::apps::spec::AppSpec;
use sedar::config::{CollectiveImpl, RunConfig, Strategy};
use sedar::coordinator::{RunOutcome, SedarRun};
use sedar::error::{FaultClass, Result, SedarError};
use sedar::replica::ReplicaCtx;
use sedar::state::{Var, VarStore};
use sedar::workfault::{self, Scenario};

fn run_scenario_under(
    sc: &Scenario,
    collectives: CollectiveImpl,
    tag: &str,
) -> RunOutcome {
    let app = MatmulApp::new(64, 4);
    let mut cfg = RunConfig::for_tests(tag);
    cfg.strategy = Strategy::SysCkpt;
    cfg.collectives = collectives;
    let spec = workfault::injection_for(&app, sc, &cfg);
    let outcome = SedarRun::new(Arc::new(app), cfg.clone(), Some(spec))
        .run()
        .unwrap();
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    outcome
}

/// Scenarios whose predictions agree across modes (every TDC, LE and TOE
/// row — `predict_native` only ever rewrites FSC rows).
fn equal_coverage_sample() -> Vec<Scenario> {
    let app = MatmulApp::new(64, 4);
    workfault::catalog(&app)
        .into_iter()
        .filter(|sc| sc.effect != FaultClass::Fsc)
        // Subsample for wall time, but keep every class: all TOE rows, the
        // paper's Table-2 representatives (2, 29), and every third row.
        .filter(|sc| sc.effect == FaultClass::Toe || sc.id == 2 || sc.id == 29 || sc.id % 3 == 0)
        .collect()
}

#[test]
fn equal_coverage_classes_behave_identically_across_modes() {
    let sample = equal_coverage_sample();
    assert!(sample.len() >= 15, "sample too thin: {}", sample.len());
    for class in [FaultClass::Tdc, FaultClass::Le, FaultClass::Toe] {
        assert!(
            sample.iter().any(|sc| sc.effect == class),
            "sample must cover {class}"
        );
    }
    for sc in sample {
        let p2p = run_scenario_under(&sc, CollectiveImpl::PointToPoint, "eqv-p2p");
        let nat = run_scenario_under(&sc, CollectiveImpl::Native, "eqv-nat");
        // Identical fault verdicts…
        assert_eq!(
            p2p.detections.first().map(|d| (d.class, d.site.clone())),
            nat.detections.first().map(|d| (d.class, d.site.clone())),
            "sc{}: first detection differs across modes",
            sc.id
        );
        assert_eq!(p2p.restarts, nat.restarts, "sc{}: N_roll differs", sc.id);
        assert_eq!(
            p2p.resume_history, nat.resume_history,
            "sc{}: recovery path differs",
            sc.id
        );
        // …and identical final stores, bit for bit.
        assert_eq!(p2p.result_correct, Some(true), "sc{}", sc.id);
        assert_eq!(nat.result_correct, Some(true), "sc{}", sc.id);
        let a = p2p.final_result.as_ref().expect("p2p completed");
        let b = nat.final_result.as_ref().expect("native completed");
        let (a, b) = (a.buf.as_f32().unwrap(), b.buf.as_f32().unwrap());
        assert_eq!(a.len(), b.len());
        assert!(
            a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
            "sc{}: final stores differ between collectives modes",
            sc.id
        );
        // Both graded against their own mode's oracle.
        let graded = [
            (&p2p, CollectiveImpl::PointToPoint),
            (&nat, CollectiveImpl::Native),
        ];
        for (outcome, mode) in graded {
            let eff = workfault::scenario_under(mode, &sc);
            let mismatches = workfault::check_prediction(&eff, outcome);
            assert!(
                mismatches.is_empty(),
                "sc{} under {:?}: {mismatches:?}",
                sc.id,
                mode
            );
        }
    }
}

#[test]
fn root_fsc_scenarios_flip_from_validate_to_collective_detection() {
    let app = MatmulApp::new(64, 4);
    let flips: Vec<Scenario> = workfault::catalog(&app)
        .into_iter()
        .filter(|sc| {
            sc.effect == FaultClass::Fsc
                && workfault::scenario_under(CollectiveImpl::Native, sc).effect == FaultClass::Tdc
        })
        .collect();
    assert!(!flips.is_empty(), "the catalog must contain root-FSC rows");
    // One representative per flipped detection site keeps the suite fast
    // while exercising both the scatter and the gather flip paths.
    let mut picked: Vec<Scenario> = Vec::new();
    for sc in &flips {
        let native = workfault::scenario_under(CollectiveImpl::Native, sc);
        if !picked
            .iter()
            .any(|p| workfault::scenario_under(CollectiveImpl::Native, p).p_det == native.p_det)
        {
            picked.push(sc.clone());
        }
    }
    assert!(picked.len() >= 2, "need a SCATTER flip and a GATHER flip");
    for sc in picked {
        let native_pred = workfault::scenario_under(CollectiveImpl::Native, &sc);
        // Undetected-until-VALIDATE under p2p…
        let p2p = run_scenario_under(&sc, CollectiveImpl::PointToPoint, "flip-p2p");
        let first = p2p.detections.first().expect("p2p run detects at VALIDATE");
        assert_eq!(first.class, FaultClass::Fsc, "sc{}", sc.id);
        assert_eq!(first.site, "VALIDATE", "sc{}", sc.id);
        assert_eq!(p2p.restarts, sc.n_roll, "sc{}", sc.id);
        // …detected at the collective under native, with the shorter
        // rollback the native oracle predicts.
        let nat = run_scenario_under(&sc, CollectiveImpl::Native, "flip-nat");
        let first = nat.detections.first().expect("native run detects early");
        assert_eq!(first.class, FaultClass::Tdc, "sc{}", sc.id);
        assert_eq!(Some(first.site.as_str()), native_pred.p_det, "sc{}", sc.id);
        assert_eq!(nat.restarts, native_pred.n_roll, "sc{}", sc.id);
        assert!(
            nat.restarts <= p2p.restarts,
            "sc{}: native detection must never cost more rollbacks",
            sc.id
        );
        // Both still end correct — coverage changed, correctness did not.
        assert_eq!(p2p.result_correct, Some(true));
        assert_eq!(nat.result_correct, Some(true));
    }
}

#[test]
fn surviving_fsc_rows_stay_fsc_under_native() {
    // C(M) corrupted after GATHER is never transmitted again: §4.2's flip
    // does not apply, and the native run must still detect at VALIDATE.
    let app = MatmulApp::new(64, 4);
    let sc = workfault::catalog(&app)
        .into_iter()
        .find(|sc| {
            sc.effect == FaultClass::Fsc
                && workfault::scenario_under(CollectiveImpl::Native, sc).effect == FaultClass::Fsc
        })
        .expect("a post-GATHER FSC row exists");
    let nat = run_scenario_under(&sc, CollectiveImpl::Native, "fsc-stays");
    let first = nat.detections.first().expect("detected at VALIDATE");
    assert_eq!(first.class, FaultClass::Fsc);
    assert_eq!(first.site, "VALIDATE");
    assert_eq!(nat.result_correct, Some(true));
}

// ---------------------------------------------------------------- deadlock

/// A minimal app whose scatter root hands over a deliberately short chunk
/// list — the exact misuse that used to strand non-root ranks in
/// `sedar_recv` (p2p) or panic on `chunks[root]` (native). The root is
/// rank 1 with a single chunk, so `chunks.len() <= root` and the
/// historical native arm indexed out of bounds.
struct ShortScatterApp {
    nranks: usize,
}

impl AppSpec for ShortScatterApp {
    fn name(&self) -> &'static str {
        "short-scatter"
    }

    fn nranks(&self) -> usize {
        self.nranks
    }

    fn n_phases(&self) -> u64 {
        2
    }

    fn phase_name(&self, phase: u64) -> String {
        match phase {
            0 => "INIT".into(),
            _ => "SCATTER".into(),
        }
    }

    fn init_store(&self, _rank: usize, seed: u64) -> VarStore {
        let mut s = VarStore::new();
        s.insert("out", Var::f32(&[2], vec![seed as f32, 0.0]));
        s
    }

    fn run_phase(&self, ctx: &mut ReplicaCtx, phase: u64) -> Result<()> {
        if phase == 0 {
            return Ok(());
        }
        // Root rank 1 supplies ONE chunk for a 4-rank world: shorter than
        // the world size AND shorter than the root index itself.
        let chunks = (ctx.rank == 1).then(|| vec![Var::f32(&[2], vec![1.0, 2.0])]);
        ctx.scatter(1, chunks, "out", "SCATTER")?;
        Ok(())
    }

    fn significant_vars(&self, _rank: usize) -> Vec<String> {
        vec!["out".into()]
    }

    fn result_var(&self) -> &'static str {
        "out"
    }

    fn expected_result(&self, seed: u64) -> Vec<f32> {
        vec![seed as f32, 0.0]
    }

    fn ckpt_phases(&self) -> Vec<u64> {
        vec![]
    }
}

#[test]
fn short_chunk_list_fails_fast_instead_of_deadlocking() {
    for (mode, tag) in [
        (CollectiveImpl::PointToPoint, "short-p2p"),
        (CollectiveImpl::Native, "short-nat"),
    ] {
        let mut cfg = RunConfig::for_tests(tag);
        cfg.strategy = Strategy::DetectOnly;
        cfg.collectives = mode;
        let run_dir = cfg.run_dir.clone();
        let result = SedarRun::new(Arc::new(ShortScatterApp { nranks: 4 }), cfg, None).run();
        // A real error — before the fix this was Ok(a gave-up outcome whose
        // every attempt carried a bogus TOE verdict) in p2p mode and a
        // replica-thread panic (`chunks[root]` out of bounds) in native
        // mode; now the root refuses the malformed chunk list up front.
        let err = result.expect_err("short chunk list must be an error, not a verdict");
        assert!(
            matches!(err, SedarError::Vmpi(_)),
            "{tag}: expected a Vmpi error, got {err}"
        );
        assert!(err.to_string().contains("chunks"), "{tag}: {err}");
        let _ = std::fs::remove_dir_all(&run_dir);
    }
}
