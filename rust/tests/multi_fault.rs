//! Multiple independent faults in one execution (§3.2, §4.2's discussion):
//! SEDAR's recovery remains *correct* — possibly at sub-optimal cost,
//! because Algorithm 1 assumes a re-detected fault is the same fault and
//! may roll back further than strictly necessary.

use std::sync::Arc;

use sedar::apps::matmul::{phases, MatmulApp};
use sedar::apps::spec::AppSpec;
use sedar::config::{RunConfig, Strategy};
use sedar::coordinator::SedarRun;
use sedar::inject::{InjectKind, InjectPoint, InjectionSpec};

fn flip(name: &str, phase: u64, rank: usize, var: &str, elem: usize) -> InjectionSpec {
    InjectionSpec {
        name: name.into(),
        point: InjectPoint::BeforePhase(phase),
        rank,
        replica: 1,
        kind: InjectKind::BitFlip {
            var: var.into(),
            elem,
            bit: 30,
        },
    }
}

fn cfg(tag: &str, strategy: Strategy) -> RunConfig {
    let mut c = RunConfig::for_tests(tag);
    c.strategy = strategy;
    c
}

#[test]
fn two_faults_different_ranks_sysckpt_recovers() {
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(64, 4));
    // Fault 1: worker 1's A_chunk after SCATTER → TDC at GATHER.
    // Fault 2: master's C after GATHER → FSC at VALIDATE.
    // Fault 1 fires first; its recovery replays from a checkpoint, after
    // which fault 2 (latched separately) still fires later.
    let outcome = SedarRun::new_multi(
        app,
        cfg("mf-two", Strategy::SysCkpt),
        vec![
            flip("f1", phases::CK1, 1, "A_chunk", 5),
            flip("f2", phases::CK3, 0, "C", 9),
        ],
    )
    .run()
    .unwrap();
    assert!(outcome.completed, "did not complete");
    assert_eq!(outcome.result_correct, Some(true));
    assert!(outcome.injected, "both faults must have fired");
    // Both faults were detected (at least two detections overall).
    assert!(
        outcome.detections.len() >= 2,
        "expected ≥2 detections, got {:?}",
        outcome.detections
    );
    // A reliable conclusion despite multiple faults — the paper's claim.
}

#[test]
fn two_faults_same_rank_userckpt_single_rollback_each() {
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(64, 4));
    let outcome = SedarRun::new_multi(
        app,
        cfg("mf-user", Strategy::UserCkpt),
        vec![
            // Corrupt A_chunk before CK1 → caught at CK1 validation.
            flip("f1", phases::CK1, 1, "A_chunk", 5),
            // Corrupt C before CK3 → caught at CK3 validation.
            flip("f2", phases::CK3, 0, "C", 9),
        ],
    )
    .run()
    .unwrap();
    assert!(outcome.completed);
    assert_eq!(outcome.result_correct, Some(true));
    // Each fault costs exactly one rollback under Algorithm 2.
    assert_eq!(outcome.restarts, 2);
    for d in &outcome.detections {
        assert_eq!(d.class, sedar::error::FaultClass::CkptCorrupt);
    }
}

#[test]
fn three_faults_detect_only_relaunches_until_clean() {
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(64, 4));
    let outcome = SedarRun::new_multi(
        app,
        cfg("mf-detect", Strategy::DetectOnly),
        vec![
            // A(W) element (worker 2's rows): TDC at SCATTER — aborts the
            // first attempt before the later faults' windows are reached.
            flip("f1", phases::SCATTER, 0, "A", (2 * 16 + 1) * 64 + 5),
            flip("f2", phases::BCAST, 0, "B", 8),
            flip("f3", phases::CK3, 0, "C", 3),
        ],
    )
    .run()
    .unwrap();
    assert!(outcome.completed);
    assert_eq!(outcome.result_correct, Some(true));
    // The faults fire in successive attempts (each attempt aborts before
    // the next fault's window): TDC@SCATTER, then TDC@BCAST, then
    // FSC@VALIDATE — three relaunches, then a clean pass.
    assert_eq!(outcome.restarts, 3);
}

#[test]
fn same_fault_position_on_both_replicas_is_undetectable_but_flagged() {
    // The paper's §3.1 vulnerability: identical corruption in BOTH replicas
    // escapes comparison-based detection. We verify the system behaves as
    // documented: run completes, no detection, and the oracle check exposes
    // the wrong result (the run reports result_correct = false).
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(64, 4));
    let mk = |replica: usize| InjectionSpec {
        name: format!("sym-{replica}"),
        point: InjectPoint::BeforePhase(phases::CK3),
        rank: 0,
        replica,
        kind: InjectKind::BitFlip {
            var: "C".into(),
            elem: 11,
            bit: 30,
        },
    };
    let outcome = SedarRun::new_multi(
        app,
        cfg("mf-sym", Strategy::SysCkpt),
        vec![mk(0), mk(1)],
    )
    .run()
    .unwrap();
    assert!(outcome.completed);
    assert!(outcome.detections.is_empty(), "symmetric corruption is invisible to comparison");
    assert_eq!(
        outcome.result_correct,
        Some(false),
        "oracle must expose the silent corruption"
    );
}
