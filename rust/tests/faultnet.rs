//! Network-fault robustness: the poison paths and the fail-safe oracle.
//!
//! The PR-8 contract for perturbed transports is *fail-safe, never
//! fail-silent*: a dropped collective message must end the world in the
//! virtual clock's all-blocked poison error (or a TOE when a recv deadline
//! is armed) — never a hang and never a silently wrong result — and a
//! faulted campaign slice must grade clean against the safety oracle and
//! reproduce byte-identically.

use std::sync::{Arc, Mutex};

use sedar::campaign::{run_campaign, CampaignSpec};
use sedar::error::SedarError;
use sedar::faultnet::{FaultLayer, FaultPlan, NetFaultMode};
use sedar::state::Var;
use sedar::util::clock::Clock;
use sedar::vmpi::{Endpoint, Network};

fn v(data: &[f32]) -> Var {
    Var::f32(&[data.len()], data.to_vec())
}

/// Run a 4-rank world under a deadline-free Drop fault layer on the
/// virtual clock and collect each rank's terminal `Result`. The world must
/// terminate (join returns) whatever the plan does.
fn dropped_world<F>(seed: u64, body: F) -> Vec<Result<(), SedarError>>
where
    F: Fn(Endpoint) -> Result<(), SedarError> + Send + Sync + Clone + 'static,
{
    const N: usize = 4;
    let clock = Clock::virtual_clock();
    clock.join_n(N);
    let layer = Arc::new(FaultLayer::new(
        FaultPlan::new(NetFaultMode::Drop, seed),
        1,
        // No recv deadline: a dropped message leaves its receiver blocked
        // forever, and ending the world is the poison detector's job.
        None,
    ));
    let net = Network::with_faults(N, clock.clone(), Some(layer));
    let results = Arc::new(Mutex::new(Vec::new()));
    let mut handles = Vec::new();
    for r in 0..N {
        let ep = net.endpoint(r);
        let body = body.clone();
        let clock = clock.clone();
        let results = Arc::clone(&results);
        handles.push(std::thread::spawn(move || {
            let _g = clock.guard();
            let out = body(ep);
            results.lock().unwrap().push(out);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    Arc::try_unwrap(results).unwrap().into_inner().unwrap()
}

/// Drive `rounds` root-0 scatters through `body`, trying seeds until one
/// plan actually drops a message (each seed's plan is deterministic, so
/// the whole search is too). Asserts the fail-safe outcome: the world
/// ends, and the blocked ranks surface the all-blocked poison error.
fn assert_drop_poisons<F>(what: &str, body: F)
where
    F: Fn(Endpoint) -> Result<(), SedarError> + Send + Sync + Clone + 'static,
{
    for seed in 1..=8u64 {
        let results = dropped_world(seed, body.clone());
        assert_eq!(results.len(), 4, "{what}: a rank hung or vanished");
        let errs: Vec<String> = results
            .iter()
            .filter_map(|r| r.as_ref().err().map(|e| e.to_string()))
            .collect();
        if errs.is_empty() {
            // This seed's plan delivered everything it needed; try the next.
            continue;
        }
        assert!(
            errs.iter().any(|e| e.contains("deadlock")),
            "{what}: dropped collective ended without the poison error: {errs:?}"
        );
        return;
    }
    panic!("{what}: no seed in 1..=8 dropped a message — plan generator suspect");
}

#[test]
fn dropped_scatter_poisons_not_hangs_p2p() {
    // Hand-rolled point-to-point scatter: root 0 sends one chunk per rank
    // per round; everyone else blocks in a deadline-free recv.
    assert_drop_poisons("p2p scatter", |ep: Endpoint| {
        for round in 0..32u32 {
            if ep.rank() == 0 {
                for dst in 1..ep.nranks() {
                    ep.send(dst, 64 + round, v(&[round as f32, dst as f32]))?;
                }
            } else {
                ep.recv(0, 64 + round)?;
            }
        }
        Ok(())
    });
}

#[test]
fn dropped_scatter_poisons_not_hangs_native() {
    // The optimized native collective over the same faulted transport.
    assert_drop_poisons("native scatter", |ep: Endpoint| {
        for round in 0..32u32 {
            let chunks = (ep.rank() == 0)
                .then(|| (0..ep.nranks()).map(|r| v(&[round as f32, r as f32])).collect());
            ep.scatter(0, chunks)?;
        }
        Ok(())
    });
}

fn slice_spec(filter: &str) -> CampaignSpec {
    let mut spec = CampaignSpec::new(42);
    spec.jobs = 2;
    spec.echo = false;
    spec.apply_filter(filter).unwrap();
    spec.base.run_dir = std::env::temp_dir().join(format!(
        "sedar-faultnet-slice-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    spec
}

#[test]
fn corrupt_slice_holds_the_safety_oracle() {
    // Every corrupt-transport cell must be fail-safe: either the world
    // completes with a validated-correct result (the corruption hit a
    // replica-absorbed path) or it stops with a detection (TDC via the
    // transport CRC). `grade_netfault` fails any other shape, so a clean
    // verdict IS the oracle check.
    let spec = slice_spec(
        "scenario=1-2,app=matmul,strategy=detect,collectives=p2p,netfault=corrupt",
    );
    let report = run_campaign(&spec).unwrap();
    let _ = std::fs::remove_dir_all(&spec.base.run_dir);
    assert!(report.verdict(), "oracle violated:\n{}", report.deterministic_report());
    assert!(
        report.deterministic_report().contains("corrupt"),
        "report must carry the netfault axis column"
    );
}

#[test]
fn mixed_slice_terminates_and_reproduces_byte_identically() {
    // The mixed plan exercises drop, dup, reorder and corrupt in one
    // world. Two full executions of the slice must render the same bytes
    // — the determinism claim `sedar conform` checks at scale — and the
    // virtual clock must bound every timeout so the test itself is the
    // no-hang check.
    let run = |tag: &str| {
        let mut spec = slice_spec(
            "scenario=1-2,app=matmul,strategy=detect,collectives=native,netfault=mixed",
        );
        spec.base.run_dir = spec.base.run_dir.join(tag);
        let report = run_campaign(&spec).unwrap();
        let _ = std::fs::remove_dir_all(&spec.base.run_dir);
        (report.verdict(), report.deterministic_report())
    };
    let (ok_a, a) = run("a");
    let (ok_b, b) = run("b");
    assert!(ok_a, "mixed slice violated the oracle:\n{a}");
    assert!(ok_b);
    assert_eq!(a, b, "same seed + same slice must render identical reports");
}
