//! The full 64-scenario workfault campaign (§4.1–4.2): every scenario is
//! injected for real and every prediction column (effect, P_det, P_rec,
//! N_roll) is checked. This is the paper's Table-2 validation, mechanized.

use sedar::apps::matmul::MatmulApp;
use sedar::config::RunConfig;
use sedar::error::FaultClass;
use sedar::workfault;

#[test]
fn all_64_scenarios_behave_as_predicted() {
    let app = MatmulApp::new(64, 4);
    let cfg = RunConfig::for_tests("campaign64");
    let catalog = workfault::catalog(&app);
    assert_eq!(catalog.len(), 64);

    let mut failures = Vec::new();
    for sc in &catalog {
        let r = workfault::run_scenario(&app, sc, &cfg).unwrap();
        if !r.pass {
            failures.push(format!("scenario {}: {:?}", sc.id, r.mismatches));
        }
    }
    assert!(
        failures.is_empty(),
        "{} scenario(s) diverged:\n{}",
        failures.len(),
        failures.join("\n")
    );
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
}

#[test]
fn effect_class_census_is_plausible() {
    // The catalog must exercise all four §2 effect classes with the rough
    // proportions the dataflow dictates (most injections are latent).
    let app = MatmulApp::new(64, 4);
    let catalog = workfault::catalog(&app);
    let count = |c: FaultClass| catalog.iter().filter(|s| s.effect == c).count();
    assert_eq!(count(FaultClass::Toe), 2); // i(M), i(W)
    assert!(count(FaultClass::Tdc) >= 10);
    assert!(count(FaultClass::Fsc) >= 8);
    assert!(count(FaultClass::Le) >= 20);
    assert_eq!(
        count(FaultClass::Tdc) + count(FaultClass::Fsc) + count(FaultClass::Le) + 2,
        64
    );
}

#[test]
fn scenario_50_trace_matches_figure3_shape() {
    // Figure 3 of the paper: GATHER→CK3 C(M) corruption. The trace must
    // show: injection, FSC at VALIDATE, restart from CK3, re-detection,
    // restart from CK2, then a clean validation.
    let app = MatmulApp::new(64, 4);
    let cfg = RunConfig::for_tests("fig3");
    let sc = workfault::catalog(&app)
        .into_iter()
        .find(|s| {
            s.window == workfault::Window::GatherCk3
                && s.rank == 0
                && s.data == workfault::DataTarget::CMaster
        })
        .unwrap();
    let r = workfault::run_scenario(&app, &sc, &cfg).unwrap();
    assert!(r.pass, "{:?}", r.mismatches);
    let t = &r.outcome.trace_dump;
    let idx = |needle: &str| t.find(needle).unwrap_or_else(|| panic!("missing: {needle}"));
    // Ordered like the paper's console output.
    assert!(idx("INJECTED") < idx("FAULT FSC detected at VALIDATE"));
    assert!(idx("FAULT FSC detected at VALIDATE") < idx("resume from sys-ck3"));
    assert!(idx("resume from sys-ck3") < idx("resume from sys-ck2"));
    assert!(idx("resume from sys-ck2") < t.rfind("final result replicas agree").unwrap());
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
}
