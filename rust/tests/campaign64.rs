//! The full 64-scenario workfault campaign (§4.1–4.2): every scenario is
//! injected for real and every prediction column (effect, P_det, P_rec,
//! N_roll) is checked. This is the paper's Table-2 validation, mechanized —
//! and since the campaign engine landed, fanned over a worker pool (each
//! scenario in an isolated world, graded by the same prediction oracle).

use sedar::apps::matmul::MatmulApp;
use sedar::campaign::{run_campaign, CampaignSpec};
use sedar::config::RunConfig;
use sedar::error::FaultClass;
use sedar::workfault;

#[test]
fn all_64_scenarios_behave_as_predicted() {
    let mut spec = CampaignSpec::new(0xC0FFEE);
    spec.apply_filter("app=matmul,strategy=sys").unwrap();
    // Both collective implementations stay in the sweep: every scenario is
    // graded against its p2p prediction AND its native one (root-FSC rows
    // flip to TDC at the collective — workfault::predict_native).
    spec.jobs = 4;
    let toe_timeout = spec.base.toe_timeout;
    spec.base = RunConfig::for_tests("campaign64");
    // Keep the campaign's generous rendezvous lapse: a loaded pool must
    // never turn a descheduled-but-healthy sibling into a spurious TOE.
    spec.base.toe_timeout = toe_timeout;
    let report = run_campaign(&spec).unwrap();
    assert_eq!(report.outcomes.len(), 128);
    assert!(
        report.verdict(),
        "{} scenario(s) diverged:\n{}",
        report.failed(),
        report.deterministic_report()
    );
    let _ = std::fs::remove_dir_all(&spec.base.run_dir);
}

#[test]
fn effect_class_census_is_plausible() {
    // The catalog must exercise all four §2 effect classes with the rough
    // proportions the dataflow dictates (most injections are latent).
    let app = MatmulApp::new(64, 4);
    let catalog = workfault::catalog(&app);
    let count = |c: FaultClass| catalog.iter().filter(|s| s.effect == c).count();
    assert_eq!(count(FaultClass::Toe), 2); // i(M), i(W)
    assert!(count(FaultClass::Tdc) >= 10);
    assert!(count(FaultClass::Fsc) >= 8);
    assert!(count(FaultClass::Le) >= 20);
    assert_eq!(
        count(FaultClass::Tdc) + count(FaultClass::Fsc) + count(FaultClass::Le) + 2,
        64
    );
}

#[test]
fn scenario_50_trace_matches_figure3_shape() {
    // Figure 3 of the paper: GATHER→CK3 C(M) corruption. The trace must
    // show: injection, FSC at VALIDATE, restart from CK3, re-detection,
    // restart from CK2, then a clean validation.
    let app = MatmulApp::new(64, 4);
    let cfg = RunConfig::for_tests("fig3");
    let sc = workfault::catalog(&app)
        .into_iter()
        .find(|s| {
            s.window == workfault::Window::GatherCk3
                && s.rank == 0
                && s.data == workfault::DataTarget::CMaster
        })
        .unwrap();
    let r = workfault::run_scenario(&app, &sc, &cfg).unwrap();
    assert!(r.pass, "{:?}", r.mismatches);
    let t = &r.outcome.trace_dump;
    let idx = |needle: &str| t.find(needle).unwrap_or_else(|| panic!("missing: {needle}"));
    // Ordered like the paper's console output.
    assert!(idx("INJECTED") < idx("FAULT FSC detected at VALIDATE"));
    assert!(idx("FAULT FSC detected at VALIDATE") < idx("resume from sys-ck3"));
    assert!(idx("resume from sys-ck3") < idx("resume from sys-ck2"));
    assert!(idx("resume from sys-ck2") < t.rfind("final result replicas agree").unwrap());
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
}
