//! The virtual-time contract (PR 6):
//!
//! 1. a TOE scenario run twice under the virtual clock produces identical
//!    verdicts AND identical modeled-time stamps on the key trace lines —
//!    logical time is part of the deterministic state, not a measurement;
//! 2. a multi-minute modeled rendezvous lapse costs (almost) no wall time:
//!    the clock jumps to the deadline at quiescence instead of waiting;
//! 3. the campaign64 sweep (64 scenarios × sys-ckpt × both collectives =
//!    128 cells) renders a byte-identical deterministic report under the
//!    wall and virtual clocks — the clock mode is an execution detail,
//!    never an observable of the experiment.

use std::sync::Arc;

use sedar::apps::matmul::MatmulApp;
use sedar::campaign::{run_campaign, CampaignSpec};
use sedar::config::{RunConfig, Strategy};
use sedar::coordinator::{RunOutcome, SedarRun};
use sedar::error::FaultClass;
use sedar::util::clock::ClockMode;
use sedar::workfault;

/// Run one index-corruption (TOE) scenario under the virtual clock with a
/// deliberately huge rendezvous lapse: 60 s of modeled waiting, plus the
/// injected delay that comfortably exceeds it. Under a wall clock this run
/// would take minutes; under the virtual clock it must be near-instant.
fn toe_run_virtual(tag: &str) -> RunOutcome {
    let app = MatmulApp::new(64, 4);
    let mut cfg = RunConfig::for_tests(tag);
    cfg.strategy = Strategy::SysCkpt;
    cfg.clock = ClockMode::Virtual;
    cfg.toe_timeout = std::time::Duration::from_secs(60);
    let cat = workfault::catalog(&app);
    let sc = cat
        .iter()
        .find(|s| s.effect == FaultClass::Toe)
        .expect("catalog has TOE scenarios");
    let inj = workfault::injection_for(&app, sc, &cfg);
    let out = SedarRun::new(Arc::new(app), cfg.clone(), Some(inj))
        .run()
        .unwrap();
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    out
}

/// The deterministic skeleton of a trace: the injection and detection
/// lines, stamps included, sorted so benign cross-thread interleaving of
/// unrelated lines cannot fail the comparison.
fn key_lines(dump: &str) -> Vec<String> {
    let mut lines: Vec<String> = dump
        .lines()
        .filter(|l| l.contains("INJECTED") || l.contains("TOE"))
        .map(String::from)
        .collect();
    lines.sort();
    lines
}

#[test]
fn toe_under_virtual_clock_is_deterministic_and_instant() {
    let t0 = std::time::Instant::now();
    let a = toe_run_virtual("vclock-toe");
    let b = toe_run_virtual("vclock-toe");
    // 2× (60 s lapse + 180 s injected delay) of modeled time; if any of it
    // leaked into wall time we would blow far past this bound.
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "virtual-clock TOE runs took {:?} of wall time — modeled waiting \
         is leaking into real waiting",
        t0.elapsed()
    );

    // Identical verdicts...
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.attempts, b.attempts);
    assert_eq!(a.restarts, b.restarts);
    assert_eq!(a.result_correct, b.result_correct);
    assert!(a.injected && b.injected);
    assert_eq!(format!("{:?}", a.detections), format!("{:?}", b.detections));
    assert!(
        a.detections.iter().any(|d| d.class == FaultClass::Toe),
        "expected a TOE detection, got {:?}",
        a.detections
    );
    // ...and identical modeled-time stamps on the key trace lines: under
    // the virtual clock, *when* something happened is replayable state.
    let (ka, kb) = (key_lines(&a.trace_dump), key_lines(&b.trace_dump));
    assert!(!ka.is_empty(), "no INJECTED/TOE lines in:\n{}", a.trace_dump);
    assert_eq!(ka, kb, "tick stamps or key events diverged between runs");
    assert!(
        ka.iter().all(|l| l.contains("ms]")),
        "key lines lost their stamps: {ka:?}"
    );
    // The modeled run time saw the lapse even though the wall never did.
    assert!(
        a.wall >= std::time::Duration::from_secs(60),
        "modeled run time {:?} is shorter than the TOE lapse",
        a.wall
    );
}

#[test]
fn wall_and_virtual_campaigns_render_byte_identical_reports() {
    let report_for = |mode: ClockMode, tag: &str| {
        let mut spec = CampaignSpec::new(0xC0FFEE);
        spec.apply_filter("app=matmul,strategy=sys").unwrap();
        spec.jobs = 4;
        let toe_timeout = spec.base.toe_timeout;
        spec.base = RunConfig::for_tests(tag);
        // Keep the campaign's generous rendezvous lapse: under the wall
        // clock a loaded pool must never turn a descheduled-but-healthy
        // sibling into a spurious TOE.
        spec.base.toe_timeout = toe_timeout;
        spec.base.clock = mode;
        let report = run_campaign(&spec).unwrap();
        let _ = std::fs::remove_dir_all(&spec.base.run_dir);
        report
    };
    let virt = report_for(ClockMode::Virtual, "clockeq-virt");
    let wall = report_for(ClockMode::Wall, "clockeq-wall");
    assert_eq!(virt.outcomes.len(), 128);
    assert!(
        virt.verdict(),
        "virtual-clock campaign diverged from the oracle:\n{}",
        virt.deterministic_report()
    );
    assert_eq!(
        wall.deterministic_report(),
        virt.deterministic_report(),
        "the clock mode leaked into the deterministic report"
    );
}
