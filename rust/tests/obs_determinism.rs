//! The observability layer's determinism contract (PR 7):
//!
//! 1. a TOE scenario run twice under the virtual clock produces a
//!    byte-identical typed event log ([`sedar::obs`]) and an identical
//!    [`sedar::metrics::MetricsSnapshot`] — the observability layer is
//!    replayable state, not a measurement;
//! 2. the Chrome trace export carries exactly one instant per typed event
//!    (the round-trip the `sedar trace export` CLI relies on);
//! 3. splitting a sweep into N shards and aggregating the pieces renders a
//!    "Table 3 (measured vs model)" section byte-identical to the
//!    single-process run — work counters merge associatively.

use std::sync::Arc;

use sedar::apps::matmul::MatmulApp;
use sedar::campaign::scheduler::null_sink;
use sedar::campaign::{build_tasks, run_campaign, run_tasks, CampaignReport, CampaignSpec};
use sedar::config::{RunConfig, Strategy};
use sedar::coordinator::{RunOutcome, SedarRun};
use sedar::error::FaultClass;
use sedar::util::clock::ClockMode;
use sedar::workfault;

/// One index-corruption (TOE) run under the virtual clock — the scenario
/// with the richest event mix (injection, TOE expiry, rollback, resume).
fn toe_run_virtual(tag: &str) -> RunOutcome {
    let app = MatmulApp::new(64, 4);
    let mut cfg = RunConfig::for_tests(tag);
    cfg.strategy = Strategy::SysCkpt;
    cfg.clock = ClockMode::Virtual;
    cfg.toe_timeout = std::time::Duration::from_secs(60);
    let cat = workfault::catalog(&app);
    let sc = cat
        .iter()
        .find(|s| s.effect == FaultClass::Toe)
        .expect("catalog has TOE scenarios");
    let inj = workfault::injection_for(&app, sc, &cfg);
    let out = SedarRun::new(Arc::new(app), cfg.clone(), Some(inj))
        .run()
        .unwrap();
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    out
}

#[test]
fn typed_event_log_and_metrics_are_repeat_run_identical() {
    let a = toe_run_virtual("obsdet-a");
    let b = toe_run_virtual("obsdet-b");

    assert!(!a.events.is_empty(), "TOE run produced no typed events");
    assert!(!a.spans.is_empty(), "TOE run produced no phase spans");
    assert_eq!(
        a.metrics, b.metrics,
        "repeat virtual-clock runs disagree on the metrics snapshot"
    );
    // The strongest form of the contract: the serialized log — ticks,
    // ranks, kinds, details, span boundaries, CRCs — is byte-identical.
    let log_a = sedar::obs::encode_log(&a.events, &a.spans);
    let log_b = sedar::obs::encode_log(&b.events, &b.spans);
    assert_eq!(
        log_a, log_b,
        "typed event logs diverged between identical virtual-clock runs"
    );

    // The Chrome export round-trips the event count: one "ph":"i" instant
    // per typed event, one "ph":"X" slice per span.
    let json = sedar::obs::chrome_json(&a.events, &a.spans);
    assert_eq!(json.matches("\"ph\":\"i\"").count(), a.events.len());
    assert_eq!(json.matches("\"ph\":\"X\"").count(), a.spans.len());
}

/// The `campaign_determinism` slice (scenarios 2, 29, 50 across every app,
/// strategy and collective — 54 cells) with a per-test run dir.
fn small_spec(tag: &str, jobs: usize) -> CampaignSpec {
    let mut spec = CampaignSpec::new(42);
    spec.apply_filter("scenario=2,scenario=29,scenario=50")
        .unwrap();
    spec.jobs = jobs;
    let toe_timeout = spec.base.toe_timeout;
    let mut base = RunConfig::for_tests(tag);
    base.run_dir = std::env::temp_dir().join(format!(
        "sedar-obsdet-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    base.toe_timeout = toe_timeout;
    spec.base = base;
    spec
}

/// The "## Table 3 (measured vs model)" section of a deterministic report.
fn table3_section(report: &str) -> &str {
    let at = report
        .find("## Table 3 (measured vs model)")
        .expect("report is missing the measured Table 3 section");
    &report[at..]
}

#[test]
fn shard_split_table3_measured_matches_single_process_run() {
    // Single-process reference sweep.
    let spec_whole = small_spec("whole", 2);
    let whole = run_campaign(&spec_whole).unwrap();
    let report_whole = whole.deterministic_report();

    // The same sweep as three shards, each run through the worker pool
    // separately, then aggregated exactly like `sedar merge` does.
    let spec_shards = small_spec("shards", 2);
    let tasks = build_tasks(&spec_shards);
    assert_eq!(tasks.len(), 54);
    let mut outcomes = Vec::new();
    for chunk in tasks.chunks(tasks.len().div_ceil(3)) {
        outcomes.extend(run_tasks(&spec_shards, chunk, &null_sink()).unwrap());
    }
    let merged = CampaignReport::new(spec_shards.seed, outcomes);
    let report_merged = merged.deterministic_report();

    let t3 = table3_section(&report_whole);
    assert!(
        t3.contains("f_d (meas)") && t3.contains("ovh (model)"),
        "measured Table 3 lost its columns:\n{t3}"
    );
    assert_eq!(
        t3,
        table3_section(&report_merged),
        "shard split changed the measured Table 3"
    );
    // And not just the table: the whole report is shard-invariant.
    assert_eq!(report_whole, report_merged);

    let _ = std::fs::remove_dir_all(&spec_whole.base.run_dir);
    let _ = std::fs::remove_dir_all(&spec_shards.base.run_dir);
}
