//! The fleet's headline invariant: splitting the sweep into any `N`
//! shards, writing one WAL each, merging them and rendering must produce
//! a report **byte-identical** to the single-process run with the same
//! `--seed`. Task outcomes are pure functions of task seeds, and task
//! seeds never see shard geometry — so sharding is pure partition.
//!
//! (The CLI-level twin of this test is the CI sharded-sweep smoke job,
//! which runs `sedar campaign --shard i/2 --wal` twice, `sedar merge`s the
//! WALs and `diff`s against the single-process report.)

use sedar::campaign::aggregate::IncrementalMerger;
use sedar::campaign::{run_campaign, CampaignReport, CampaignSpec};
use sedar::config::RunConfig;
use sedar::fleet::plan::ShardPlan;
use sedar::fleet::snapshot::{merge_wals, read_wal};
use sedar::fleet::{run_shard, FleetOptions};

/// The representative slice the determinism suite uses: one TDC, one LE
/// and one FSC scenario across every app, strategy and collectives mode
/// (54 tasks).
fn small_spec(tag: &str) -> CampaignSpec {
    let mut spec = CampaignSpec::new(42);
    spec.apply_filter("scenario=2,scenario=29,scenario=50").unwrap();
    spec.jobs = 2;
    let toe_timeout = spec.base.toe_timeout;
    let mut base = RunConfig::for_tests(tag);
    base.run_dir = std::env::temp_dir().join(format!(
        "sedar-fleet-eq-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    // Keep the campaign's generous rendezvous lapse: a loaded pool must
    // never turn a descheduled-but-healthy sibling into a spurious TOE.
    base.toe_timeout = toe_timeout;
    spec.base = base;
    spec
}

fn tmpfile(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "sedar-fleet-eq-{tag}-{}-{:?}.wal",
        std::process::id(),
        std::thread::current().id()
    ))
}

#[test]
fn two_way_split_merges_byte_identical() {
    // Single-process reference run.
    let spec_single = small_spec("single");
    let reference = run_campaign(&spec_single).unwrap();
    assert_eq!(reference.outcomes.len(), 54);

    // The same sweep as two shard processes, each writing one WAL.
    let mut paths = Vec::new();
    for i in 1..=2usize {
        let spec = small_spec(&format!("shard{i}"));
        let out = tmpfile(&format!("shard{i}"));
        let _ = std::fs::remove_file(&out);
        let run = run_shard(
            &spec,
            &FleetOptions {
                plan: Some(ShardPlan::parse(&format!("{i}/2")).unwrap()),
                wal_path: Some(out.clone()),
                ..FleetOptions::default()
            },
        )
        .unwrap();
        assert_eq!(run.executed, run.owned, "fresh WAL: everything executes");
        assert!(out.exists(), "shard WAL must be written");
        paths.push(out);
        let _ = std::fs::remove_dir_all(&spec.base.run_dir);
    }

    // Merge the durable WALs (in reversed order, to also exercise
    // commutativity at the file level) and compare every rendered byte.
    let shards: Vec<_> = paths.iter().rev().map(|p| read_wal(p).unwrap()).collect();
    let (seed, total, outcomes) = merge_wals(shards).unwrap();
    assert_eq!(seed, 42);
    assert_eq!(total, 54);
    assert_eq!(outcomes.len(), 54);
    let merged = CampaignReport::new(seed, outcomes);
    assert_eq!(
        merged.deterministic_report(),
        reference.deterministic_report(),
        "sharded + merged report must be byte-identical to the single-process run"
    );
    assert_eq!(merged.csv(), reference.csv());

    // Feeding one shard's WAL twice is *idempotent* (the live merger
    // re-reads growing WALs), but two different shards claiming one index
    // is still an overlap error — covered in tests/fleet_artifact.rs.
    let dup = vec![read_wal(&paths[0]).unwrap(), read_wal(&paths[0]).unwrap()];
    let (_, _, once) = merge_wals(dup).unwrap();
    assert_eq!(once.len(), 27, "re-reading a shard must not duplicate rows");

    // A lone shard is an incomplete union — the merge surface reports the
    // coverage so `sedar merge` can refuse without --allow-partial.
    let lone = vec![read_wal(&paths[0]).unwrap()];
    let (_, total, outcomes) = merge_wals(lone).unwrap();
    assert!(
        (outcomes.len() as u64) < total,
        "a single shard of a 2-way split cannot cover the sweep"
    );

    // The live partial aggregate: stream shard 1's outcomes in first —
    // the partial union must be exactly those rows of the final report —
    // then shard 2's, after which the streamed report equals the merged
    // (and therefore the single-process) report byte-for-byte.
    let (meta1, out1) = read_wal(&paths[0]).unwrap();
    let (meta2, out2) = read_wal(&paths[1]).unwrap();
    let mut live = IncrementalMerger::new(meta1);
    live.ingest(&meta1, out1.clone()).unwrap();
    assert!(!live.is_complete());
    assert_eq!(live.done(), 27);
    // Rollup tables re-aggregate and so differ mid-flight; the per-task
    // rows are pure per-outcome functions, so every row of the partial
    // report must appear in the final one. (Markdown cell padding depends
    // on the widest row *in that table*, so compare trimmed cells, and
    // skip the width-dependent `---` separator row.)
    fn per_task_rows(report: &str) -> Vec<String> {
        let start = report.find("## Per task").expect("report has a per-task section");
        let rest = &report[start..];
        let end = rest[1..].find("\n## ").map(|i| i + 1).unwrap_or(rest.len());
        rest[..end]
            .lines()
            .filter(|l| l.starts_with('|') && !l.contains("---"))
            .map(|l| l.split('|').map(str::trim).collect::<Vec<_>>().join("|"))
            .collect()
    }
    let partial = live.report().unwrap().deterministic_report();
    let full = merged.deterministic_report();
    let full_rows = per_task_rows(&full);
    assert_eq!(full_rows.len(), 55, "54 task rows + header");
    for row in per_task_rows(&partial) {
        assert!(
            full_rows.contains(&row),
            "partial row missing from the final report: {row}"
        );
    }
    // Re-ingesting the same shard mid-flight is the supervisor's normal
    // tailing pattern; the union must not change.
    live.ingest(&meta1, out1).unwrap();
    assert_eq!(live.done(), 27);
    live.ingest(&meta2, out2).unwrap();
    assert!(live.is_complete());
    assert_eq!(
        live.report().unwrap().deterministic_report(),
        full,
        "live aggregate at completion must equal the final merged report"
    );

    for p in paths {
        let _ = std::fs::remove_file(p);
    }
    let _ = std::fs::remove_dir_all(&spec_single.base.run_dir);
}
