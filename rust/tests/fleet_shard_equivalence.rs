//! The fleet's headline invariant: splitting the sweep into any `N`
//! shards, writing durable artifacts, merging them and rendering must
//! produce a report **byte-identical** to the single-process run with the
//! same `--seed`. Task outcomes are pure functions of task seeds, and task
//! seeds never see shard geometry — so sharding is pure partition.
//!
//! (The CLI-level twin of this test is the CI sharded-sweep smoke job,
//! which runs `sedar campaign --shard i/2 --out` twice, `sedar merge`s the
//! artifacts and `diff`s against the single-process report.)

use sedar::campaign::{run_campaign, CampaignReport, CampaignSpec};
use sedar::config::RunConfig;
use sedar::fleet::plan::ShardPlan;
use sedar::fleet::{artifact, run_shard, FleetOptions};

/// The representative slice the determinism suite uses: one TDC, one LE
/// and one FSC scenario across every app, strategy and collectives mode
/// (54 tasks).
fn small_spec(tag: &str) -> CampaignSpec {
    let mut spec = CampaignSpec::new(42);
    spec.apply_filter("scenario=2,scenario=29,scenario=50").unwrap();
    spec.jobs = 2;
    let toe_timeout = spec.base.toe_timeout;
    let mut base = RunConfig::for_tests(tag);
    base.run_dir = std::env::temp_dir().join(format!(
        "sedar-fleet-eq-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    // Keep the campaign's generous rendezvous lapse: a loaded pool must
    // never turn a descheduled-but-healthy sibling into a spurious TOE.
    base.toe_timeout = toe_timeout;
    spec.base = base;
    spec
}

fn tmpfile(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "sedar-fleet-eq-{tag}-{}-{:?}.bin",
        std::process::id(),
        std::thread::current().id()
    ))
}

#[test]
fn two_way_split_merges_byte_identical() {
    // Single-process reference run.
    let spec_single = small_spec("single");
    let reference = run_campaign(&spec_single).unwrap();
    assert_eq!(reference.outcomes.len(), 54);

    // The same sweep as two shard processes, each writing an artifact.
    let mut paths = Vec::new();
    for i in 1..=2usize {
        let spec = small_spec(&format!("shard{i}"));
        let out = tmpfile(&format!("shard{i}"));
        let _ = std::fs::remove_file(&out);
        let run = run_shard(
            &spec,
            &FleetOptions {
                plan: Some(ShardPlan::parse(&format!("{i}/2")).unwrap()),
                artifact_path: Some(out.clone()),
                ..FleetOptions::default()
            },
        )
        .unwrap();
        assert_eq!(run.executed, run.owned, "no journal: everything executes");
        assert!(out.exists(), "shard artifact must be written");
        paths.push(out);
        let _ = std::fs::remove_dir_all(&spec.base.run_dir);
    }

    // Merge the durable artifacts (in reversed order, to also exercise
    // commutativity at the file level) and compare every rendered byte.
    let shards: Vec<_> = paths
        .iter()
        .rev()
        .map(|p| artifact::read_artifact(p).unwrap())
        .collect();
    let (seed, total, outcomes) = artifact::merge_artifacts(shards).unwrap();
    assert_eq!(seed, 42);
    assert_eq!(total, 54);
    assert_eq!(outcomes.len(), 54);
    let merged = CampaignReport::new(seed, outcomes);
    assert_eq!(
        merged.deterministic_report(),
        reference.deterministic_report(),
        "sharded + merged report must be byte-identical to the single-process run"
    );
    assert_eq!(merged.csv(), reference.csv());

    // Overlapping shards must be rejected at merge time: feed shard 1's
    // artifact twice.
    let dup = vec![
        artifact::read_artifact(&paths[0]).unwrap(),
        artifact::read_artifact(&paths[0]).unwrap(),
    ];
    assert!(artifact::merge_artifacts(dup).is_err());

    // A lone shard is an incomplete union — the merge surface reports the
    // coverage so `sedar merge` can refuse without --allow-partial.
    let lone = vec![artifact::read_artifact(&paths[0]).unwrap()];
    let (_, total, outcomes) = artifact::merge_artifacts(lone).unwrap();
    assert!(
        (outcomes.len() as u64) < total,
        "a single shard of a 2-way split cannot cover the sweep"
    );

    for p in paths {
        let _ = std::fs::remove_file(p);
    }
    let _ = std::fs::remove_dir_all(&spec_single.base.run_dir);
}
