//! Checkpoint-substrate integration: chains across simulated process
//! restarts, storage-corruption detection, dirty-state fidelity, and
//! property tests on the chain invariants.

use std::path::PathBuf;

use sedar::checkpoint::snapshot::{read_frame, write_frame, Codec};
use sedar::checkpoint::user::UserSnapshot;
use sedar::checkpoint::{RankSnapshot, SystemChain, UserChain};
use sedar::prop::{forall, Gen};
use sedar::state::{Var, VarStore};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "sedar-it-ckpt-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn store_from(g: &mut Gen) -> VarStore {
    let mut s = VarStore::new();
    let nvars = g.usize_range(1, 6);
    for i in 0..nvars {
        let len = g.usize_range(1, 64);
        s.insert(&format!("v{i}"), Var::f32(&[len], g.vec_f32(len)));
    }
    s.insert("counter", Var::i64_scalar(g.u64() as i64));
    s
}

#[test]
fn prop_rank_snapshot_roundtrip_any_store() {
    forall("RankSnapshot serialize/deserialize", 40, |g| {
        let snap = RankSnapshot {
            cursor: g.u64() % 1000,
            stores: [store_from(g), store_from(g)],
        };
        let back = RankSnapshot::deserialize(&snap.serialize()).unwrap();
        assert_eq!(back, snap);
    });
}

#[test]
fn prop_frame_roundtrip_any_payload_any_codec() {
    forall("frame write/read", 30, |g| {
        let dir = tmpdir("frame");
        let len = g.usize_range(0, 5000);
        let payload = g.vec_u8(len);
        let codec = if g.bool() {
            Codec::Raw
        } else {
            Codec::Deflate(g.usize_range(1, 9) as u32)
        };
        let p = dir.join("f.bin");
        write_frame(&p, &payload, codec).unwrap();
        assert_eq!(read_frame(&p).unwrap(), payload);
        std::fs::remove_dir_all(&dir).unwrap();
    });
}

#[test]
fn prop_frame_rejects_any_single_byte_corruption() {
    forall("frame CRC catches flips", 20, |g| {
        let dir = tmpdir("crcflip");
        let len = g.usize_range(32, 600);
        let payload = g.vec_u8(len);
        let p = dir.join("f.bin");
        write_frame(&p, &payload, Codec::Raw).unwrap();
        let mut raw = std::fs::read(&p).unwrap();
        // Flip one byte in the body (past the 24-byte header).
        let idx = 24 + g.usize_range(0, raw.len() - 24);
        raw[idx] ^= 1 << g.usize_range(0, 8);
        std::fs::write(&p, &raw).unwrap();
        assert!(read_frame(&p).is_err(), "corruption not detected");
        std::fs::remove_dir_all(&dir).unwrap();
    });
}

#[test]
fn chain_survives_process_restart() {
    // Simulate dmtcp_restart across a process boundary: create, drop,
    // reopen, walk backwards.
    let dir = tmpdir("restart");
    let nranks = 3;
    {
        let chain = SystemChain::create(&dir, nranks, Codec::Deflate(1)).unwrap();
        for no in 0..4u64 {
            for rank in 0..nranks {
                let mut s = VarStore::new();
                s.insert("x", Var::f32(&[2], vec![no as f32, rank as f32]));
                let snap = RankSnapshot {
                    cursor: no * 2 + 1,
                    stores: [s.clone(), s],
                };
                chain.write(no, rank, &snap).unwrap();
            }
            chain.commit(no).unwrap();
        }
    }
    let chain = SystemChain::open(&dir, nranks, Codec::Deflate(1)).unwrap();
    assert_eq!(chain.count().unwrap(), 4);
    for no in (0..4u64).rev() {
        for rank in 0..nranks {
            let snap = chain.read(no, rank).unwrap();
            assert_eq!(snap.cursor, no * 2 + 1);
            assert_eq!(
                snap.stores[0].f32("x").unwrap(),
                &[no as f32, rank as f32]
            );
        }
    }
    assert!(chain.disk_bytes().unwrap() > 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn prop_algorithm1_walk_terminates_and_is_monotone() {
    forall("Algorithm 1 walk", 50, |g| {
        let count = g.u64() % 10;
        let mut prev = i64::MAX;
        for counter in 1..=(count as u32 + 2) {
            match sedar::recovery::algorithm1_target(count, counter) {
                Some(k) => {
                    assert!((k as i64) < prev, "walk must strictly descend");
                    assert!(k < count, "target must be a stored checkpoint");
                    prev = k as i64;
                }
                None => {
                    // Once exhausted, stays exhausted.
                    assert!(
                        sedar::recovery::algorithm1_target(count, counter + 1).is_none()
                    );
                }
            }
        }
    });
}

#[test]
fn prop_user_chain_single_valid_invariant() {
    forall("user chain keeps exactly one valid ckpt", 15, |g| {
        let dir = tmpdir("uinv");
        let chain = UserChain::create(&dir, 1, Codec::Raw).unwrap();
        let mut valid_no: Option<u64> = None;
        let steps = g.usize_range(1, 8);
        for no in 0..steps as u64 {
            let snap = UserSnapshot {
                cursor: no,
                store: store_from(g),
            };
            if g.chance(0.7) {
                chain.write_valid(no, 0, &snap).unwrap();
                chain.commit_valid(no).unwrap();
                valid_no = Some(no);
            } else {
                // corrupted candidate: discard (never committed)
                chain.discard(no).unwrap();
            }
            assert_eq!(chain.latest().unwrap(), valid_no);
            // At most one checkpoint's files on disk.
            let files = std::fs::read_dir(&dir)
                .unwrap()
                .filter(|e| {
                    e.as_ref()
                        .unwrap()
                        .file_name()
                        .to_string_lossy()
                        .starts_with("uck")
                })
                .count();
            assert!(files <= 1, "single-valid invariant violated: {files} files");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    });
}

#[test]
fn dirty_system_checkpoint_roundtrips_divergence_exactly() {
    let dir = tmpdir("dirty2");
    let chain = SystemChain::create(&dir, 1, Codec::Deflate(3)).unwrap();
    let mut s0 = VarStore::new();
    s0.insert("data", Var::f32(&[4], vec![1.0, 2.0, 3.0, 4.0]));
    let mut s1 = s0.clone();
    // Replica 1 carries a bit-flip — a silently dirty checkpoint.
    sedar::util::flip_bit(s1.get_mut("data").unwrap().buf.bytes_mut(), 9, 6);
    let snap = RankSnapshot {
        cursor: 3,
        stores: [s0.clone(), s1.clone()],
    };
    chain.write(0, 0, &snap).unwrap();
    chain.commit(0).unwrap();
    let back = chain.read(0, 0).unwrap();
    // The divergence is preserved bit-for-bit (the defining system-level
    // property that forces Algorithm 1's multi-rollback).
    assert_eq!(back.stores[0], s0);
    assert_eq!(back.stores[1], s1);
    assert_ne!(back.stores[0], back.stores[1]);
    std::fs::remove_dir_all(&dir).unwrap();
}
