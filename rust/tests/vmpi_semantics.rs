//! Integration tests of the message-passing substrate: MPI-subset semantics
//! under many ranks, interleavings and message storms, plus property tests
//! of the collectives against sequential references.

use std::sync::Arc;

use sedar::prop::{forall, Gen};
use sedar::state::Var;
use sedar::vmpi::Network;

fn v(data: Vec<f32>) -> Var {
    Var::f32(&[data.len()], data)
}

fn run_world<F>(n: usize, f: F)
where
    F: Fn(sedar::vmpi::Endpoint) + Send + Sync + 'static + Clone,
{
    let net = Network::new(n);
    let mut handles = Vec::new();
    for r in 0..n {
        let ep = net.endpoint(r);
        let f = f.clone();
        handles.push(std::thread::spawn(move || f(ep)));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn all_to_all_storm_preserves_content_and_order() {
    // Every rank sends 50 sequenced messages to every other rank; receivers
    // must see each peer's stream in order with intact payloads.
    let n = 6;
    run_world(n, move |ep| {
        let me = ep.rank();
        for dst in 0..n {
            if dst == me {
                continue;
            }
            for seq in 0..50 {
                ep.send(dst, 5, v(vec![me as f32, seq as f32])).unwrap();
            }
        }
        for src in 0..n {
            if src == me {
                continue;
            }
            for seq in 0..50 {
                let m = ep.recv(src, 5).unwrap();
                let d = m.buf.as_f32().unwrap();
                assert_eq!(d[0] as usize, src);
                assert_eq!(d[1] as usize, seq);
            }
        }
    });
}

#[test]
fn scatter_gather_roundtrip_many_ranks() {
    let n = 8;
    run_world(n, move |ep| {
        let chunks = (ep.rank() == 0).then(|| {
            (0..n)
                .map(|i| v(vec![i as f32 * 3.0, i as f32 * 3.0 + 1.0]))
                .collect::<Vec<_>>()
        });
        let mine = ep.scatter(0, chunks).unwrap();
        // transform and gather back
        let d = mine.buf.as_f32().unwrap();
        let doubled = v(d.iter().map(|x| x * 2.0).collect());
        let all = ep.gather(0, doubled).unwrap();
        if ep.rank() == 0 {
            for (i, c) in all.unwrap().iter().enumerate() {
                let d = c.buf.as_f32().unwrap();
                assert_eq!(d, &[i as f32 * 6.0, (i as f32 * 3.0 + 1.0) * 2.0]);
            }
        }
    });
}

#[test]
fn bcast_from_every_root() {
    let n = 5;
    for root in 0..n {
        run_world(n, move |ep| {
            let var = (ep.rank() == root).then(|| v(vec![root as f32; 4]));
            let got = ep.bcast(root, var).unwrap();
            assert_eq!(got.buf.as_f32().unwrap(), &[root as f32; 4]);
        });
    }
}

#[test]
fn repeated_barriers_do_not_interleave() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let n = 4;
    let round = Arc::new(AtomicUsize::new(0));
    let net = Network::new(n);
    let mut handles = Vec::new();
    for r in 0..n {
        let ep = net.endpoint(r);
        let round = Arc::clone(&round);
        handles.push(std::thread::spawn(move || {
            for k in 0..20 {
                // Everyone observes the same round count at the barrier.
                ep.barrier(0).unwrap();
                let seen = round.load(Ordering::SeqCst);
                assert!(seen == k * n || seen <= (k + 1) * n);
                round.fetch_add(1, Ordering::SeqCst);
                ep.barrier(0).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn abort_unblocks_whole_world() {
    let n = 4;
    let net = Network::new(n);
    let mut handles = Vec::new();
    for r in 0..n {
        let ep = net.endpoint(r);
        handles.push(std::thread::spawn(move || {
            // Everyone waits for a message that never comes.
            ep.recv((r + 1) % 4, 1)
        }));
    }
    // No grace sleep needed: the clock's gen-counter protocol makes
    // abort-before-block and abort-while-blocked both race-free (a
    // receiver that subscribed before the abort sees the gen bump; one
    // that subscribes after sees the flag).
    net.abort();
    for h in handles {
        let err = h.join().unwrap().unwrap_err();
        assert!(matches!(err, sedar::error::SedarError::Aborted));
    }
}

#[test]
fn prop_reduce_matches_sequential_sum() {
    forall("vmpi reduce == sequential sum", 25, |g: &mut Gen| {
        let n = g.usize_range(2, 6);
        let len = g.usize_range(1, 20);
        let inputs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(len)).collect();
        let mut want = vec![0f32; len];
        for input in &inputs {
            for (w, x) in want.iter_mut().zip(input) {
                *w += x;
            }
        }
        let net = Network::new(n);
        let mut handles = Vec::new();
        for (r, data) in inputs.into_iter().enumerate() {
            let ep = net.endpoint(r);
            handles.push(std::thread::spawn(move || {
                ep.reduce_sum_f32(0, v(data)).unwrap()
            }));
        }
        let mut root_out = None;
        for (r, h) in handles.into_iter().enumerate() {
            let out = h.join().unwrap();
            if r == 0 {
                root_out = out;
            }
        }
        let got = root_out.unwrap();
        let got = got.buf.as_f32().unwrap();
        // Deterministic rank-ascending accumulation: tolerate f32 noise from
        // the reference's identical order (should be exact, in fact).
        assert_eq!(got, &want[..]);
    });
}

#[test]
fn prop_allreduce_agrees_across_ranks() {
    forall("allreduce gives every rank the same vector", 15, |g: &mut Gen| {
        let n = g.usize_range(2, 5);
        let len = g.usize_range(1, 8);
        let inputs: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(len)).collect();
        let net = Network::new(n);
        let mut handles = Vec::new();
        for (r, data) in inputs.into_iter().enumerate() {
            let ep = net.endpoint(r);
            handles.push(std::thread::spawn(move || {
                ep.allreduce_sum_f32(0, v(data)).unwrap()
            }));
        }
        let results: Vec<Vec<f32>> = handles
            .into_iter()
            .map(|h| h.join().unwrap().buf.as_f32().unwrap().to_vec())
            .collect();
        for r in &results[1..] {
            assert_eq!(r, &results[0]);
        }
    });
}
