//! Property-based invariants of the whole coordinator:
//!
//! * **Soundness** — for ANY single injected bit-flip (random window, rank,
//!   variable, element, bit), a protected run either completes with the
//!   correct result or safe-stops and recovers to the correct result. No
//!   silent corruption ever survives a SEDAR strategy.
//! * **Prediction totality** — the scenario oracle's N_roll always bounds
//!   the observed restarts for catalogued scenarios (checked exactly in
//!   campaign64; here we check random *uncatalogued* elements too).
//! * **Determinism** — fault-free runs are reproducible: same seed ⇒ same
//!   final result bytes.

use std::sync::Arc;

use sedar::apps::matmul::{phases, MatmulApp};
use sedar::apps::spec::AppSpec;
use sedar::config::{RunConfig, Strategy};
use sedar::coordinator::SedarRun;
use sedar::inject::{InjectKind, InjectPoint, InjectionSpec};
use sedar::prop::{forall, Gen};

fn test_cfg(tag: &str, strategy: Strategy, seed: u64) -> RunConfig {
    let mut c = RunConfig::for_tests(tag);
    c.strategy = strategy;
    c.seed = seed;
    c
}

/// A random single bit-flip somewhere in the matmul test app.
fn random_flip(g: &mut Gen, app: &MatmulApp) -> InjectionSpec {
    let rank = g.usize_range(0, app.nranks);
    let store = app.init_store(rank, 1);
    let vars: Vec<&str> = store.names().collect();
    let var = (*g.pick(&vars)).to_string();
    let numel = store.get(&var).unwrap().numel();
    let elem = g.usize_range(0, numel);
    // Any phase window except DURING (index faults are separate).
    let phase = g.usize_range(1, phases::COUNT as usize) as u64;
    InjectionSpec {
        name: format!("prop-flip-r{rank}-{var}-{elem}"),
        point: InjectPoint::BeforePhase(phase),
        rank,
        replica: g.usize_range(0, 2),
        kind: InjectKind::BitFlip {
            var,
            elem,
            bit: g.usize_range(0, 32) as u8,
        },
    }
}

#[test]
fn prop_any_single_flip_sysckpt_sound() {
    let app = MatmulApp::new(32, 4);
    forall("any single bit-flip is survived (sys-ckpt)", 30, |g| {
        let spec = random_flip(g, &app);
        let tag = format!("prop-sys-{}", g.u64());
        let run = SedarRun::new(
            Arc::new(app.clone()),
            test_cfg(&tag, Strategy::SysCkpt, 1),
            Some(spec.clone()),
        );
        let outcome = run.run().unwrap();
        assert!(outcome.completed, "{spec:?}: gave up");
        // Soundness: the final result is ALWAYS correct — a bit-flip either
        // was latent (no detection) or was detected and recovered.
        assert_eq!(
            outcome.result_correct,
            Some(true),
            "{spec:?}: wrong result after {} restarts, detections {:?}",
            outcome.restarts,
            outcome.detections
        );
        let _ = std::fs::remove_dir_all(&outcome_run_dir(&tag));
    });
}

#[test]
fn prop_any_single_flip_userckpt_at_most_one_rollback_per_detection() {
    let app = MatmulApp::new(32, 4);
    forall("user-ckpt never needs more than 1 rollback", 25, |g| {
        let spec = random_flip(g, &app);
        let tag = format!("prop-user-{}", g.u64());
        let outcome = SedarRun::new(
            Arc::new(app.clone()),
            test_cfg(&tag, Strategy::UserCkpt, 1),
            Some(spec.clone()),
        )
        .run()
        .unwrap();
        assert!(outcome.completed);
        assert_eq!(outcome.result_correct, Some(true), "{spec:?}");
        // §3.3: a single fault costs at most one rollback (detection latency
        // is confined within the checkpoint interval by validation).
        assert!(
            outcome.restarts <= 1,
            "{spec:?}: took {} restarts under user-ckpt",
            outcome.restarts
        );
        let _ = std::fs::remove_dir_all(&outcome_run_dir(&tag));
    });
}

#[test]
fn prop_detect_only_at_most_one_relaunch() {
    let app = MatmulApp::new(32, 4);
    forall("detect-only: ≤1 relaunch for a single fault", 20, |g| {
        let spec = random_flip(g, &app);
        let tag = format!("prop-det-{}", g.u64());
        let outcome = SedarRun::new(
            Arc::new(app.clone()),
            test_cfg(&tag, Strategy::DetectOnly, 1),
            Some(spec.clone()),
        )
        .run()
        .unwrap();
        assert!(outcome.completed);
        assert_eq!(outcome.result_correct, Some(true), "{spec:?}");
        assert!(outcome.restarts <= 1, "{spec:?}");
        // And the relaunch (if any) started from scratch.
        for r in &outcome.resume_history {
            assert!(matches!(r, sedar::recovery::ResumeFrom::Scratch));
        }
        let _ = std::fs::remove_dir_all(&outcome_run_dir(&tag));
    });
}

#[test]
fn fault_free_runs_are_deterministic() {
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(32, 4));
    let mut results = Vec::new();
    for rep in 0..3 {
        let outcome = SedarRun::new(
            app.clone(),
            test_cfg(&format!("det-rep{rep}"), Strategy::SysCkpt, 42),
            None,
        )
        .run()
        .unwrap();
        assert_eq!(outcome.result_correct, Some(true));
        results.push(outcome.trace_dump.lines().count());
    }
    // Same seed, same app ⇒ same number of trace events (the stores are
    // compared bit-exactly inside the run already; the trace shape is a
    // cheap determinism proxy across runs).
    assert_eq!(results[0], results[1]);
    assert_eq!(results[1], results[2]);
}

fn outcome_run_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "sedar-test-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ))
}
