//! Durable shard artifacts: encode→decode round-trips (including
//! non-ASCII mismatch notes and empty shards), merge idempotence and
//! commutativity across shard orders, and rejection of truncated or
//! corrupted frames.

use std::time::Duration;

use sedar::campaign::shard::TaskOutcome;
use sedar::campaign::{CampaignApp, CampaignReport};
use sedar::config::{CollectiveImpl, Strategy};
use sedar::detect::ValidationMode;
use sedar::error::FaultClass;
use sedar::faultnet::NetFaultMode;
use sedar::fleet::artifact::{merge_artifacts, read_artifact, write_artifact, ShardMeta};
use sedar::recovery::ResumeFrom;

fn tmpfile(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "sedar-artifact-{tag}-{}-{:?}.bin",
        std::process::id(),
        std::thread::current().id()
    ))
}

fn meta(index: u32, count: u32) -> ShardMeta {
    ShardMeta {
        seed: 42,
        shard_index: index,
        shard_count: count,
        total_tasks: 6,
        spec_hash: 0xF1E7_0001,
    }
}

/// An outcome exercising every optional field and non-ASCII text.
fn ornate(index: usize) -> TaskOutcome {
    TaskOutcome {
        index,
        scenario_id: 50,
        app: CampaignApp::Jacobi,
        strategy: Strategy::SysCkpt,
        collectives: CollectiveImpl::Native,
        validation: ValidationMode::Sha256,
        netfault: NetFaultMode::Mixed,
        faults: 3,
        completed: true,
        restarts: 2,
        injected: true,
        correct: Some(false),
        first_detection: Some((FaultClass::Fsc, "VALIDATE→rank0".into())),
        last_resume: Some(ResumeFrom::SysCkpt(2)),
        pass: false,
        mismatches: vec![
            "résultat faux: ≠ oracle".into(),
            "νote with emoji ✗ and cyrillic ошибка".into(),
        ],
        wall: Duration::from_millis(12),
        metrics: sedar::metrics::MetricsSnapshot {
            compare_ticks: 1,
            compare_bytes: 2,
            sync_ticks: 3,
            sync_events: 4,
            sys_ckpt_ticks: 5,
            sys_ckpt_bytes: 6,
            sys_ckpts: 7,
            user_ckpt_ticks: 8,
            user_ckpt_bytes: 9,
            user_ckpts: 10,
            exec_ticks: 11,
            execs: 12,
            rollback_ticks: 13,
            rollbacks: 14,
        },
    }
}

/// A minimal all-defaults outcome.
fn plain(index: usize) -> TaskOutcome {
    TaskOutcome {
        index,
        scenario_id: 1,
        app: CampaignApp::Matmul,
        strategy: Strategy::DetectOnly,
        collectives: CollectiveImpl::PointToPoint,
        validation: ValidationMode::Full,
        netfault: NetFaultMode::None,
        faults: 1,
        completed: true,
        restarts: 0,
        injected: true,
        correct: Some(true),
        first_detection: None,
        last_resume: None,
        pass: true,
        mismatches: vec![],
        wall: Duration::ZERO,
        metrics: Default::default(),
    }
}

#[test]
fn file_roundtrip_preserves_everything() {
    let p = tmpfile("roundtrip");
    let outcomes = vec![plain(0), ornate(2), plain(4)];
    write_artifact(&p, &meta(0, 2), &outcomes).unwrap();
    let (m, back) = read_artifact(&p).unwrap();
    assert_eq!(m, meta(0, 2));
    assert_eq!(back.len(), 3);
    // Field-for-field equality via Debug (TaskOutcome has no PartialEq).
    for (a, b) in outcomes.iter().zip(&back) {
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }
    std::fs::remove_file(&p).unwrap();
}

#[test]
fn empty_shard_roundtrips() {
    let p = tmpfile("empty");
    write_artifact(&p, &meta(1, 2), &[]).unwrap();
    let (m, back) = read_artifact(&p).unwrap();
    assert_eq!(m.shard_index, 1);
    assert!(back.is_empty());
    std::fs::remove_file(&p).unwrap();
}

#[test]
fn merge_is_idempotent_and_commutative_over_shard_order() {
    let a = (meta(0, 2), vec![plain(0), ornate(2), plain(4)]);
    let b = (meta(1, 2), vec![plain(1), plain(3), plain(5)]);
    let (seed_ab, total_ab, ab) = merge_artifacts(vec![a.clone(), b.clone()]).unwrap();
    let (seed_ba, total_ba, ba) = merge_artifacts(vec![b.clone(), a.clone()]).unwrap();
    assert_eq!((seed_ab, total_ab), (seed_ba, total_ba));
    assert_eq!(
        CampaignReport::new(seed_ab, ab).deterministic_report(),
        CampaignReport::new(seed_ba, ba).deterministic_report(),
        "merge must be commutative over shard order"
    );
    // Idempotent: merging the merged set with nothing new changes nothing.
    let (_, _, once) = merge_artifacts(vec![a.clone(), b.clone()]).unwrap();
    let (_, _, again) = merge_artifacts(vec![(meta(0, 1), once.clone())]).unwrap();
    assert_eq!(format!("{once:?}"), format!("{again:?}"));
}

#[test]
fn merge_rejects_overlap_seed_and_spec_drift() {
    // Overlapping task indices.
    let err = merge_artifacts(vec![
        (meta(0, 2), vec![plain(0)]),
        (meta(1, 2), vec![plain(0)]),
    ])
    .unwrap_err();
    assert!(err.to_string().contains("duplicate task index"), "{err}");

    // Mismatched seeds.
    let mut other_seed = meta(1, 2);
    other_seed.seed = 43;
    assert!(merge_artifacts(vec![(meta(0, 2), vec![plain(0)]), (other_seed, vec![plain(1)])])
        .is_err());

    // Mismatched filtered-sweep widths.
    let mut other_total = meta(1, 2);
    other_total.total_tasks = 7;
    assert!(merge_artifacts(vec![(meta(0, 2), vec![plain(0)]), (other_total, vec![plain(1)])])
        .is_err());

    // Same seed and width, different filter set (spec fingerprint drift —
    // e.g. scenario=1-12 vs scenario=13-24 both yield 12 tasks).
    let mut other_spec = meta(1, 2);
    other_spec.spec_hash = 0xF1E7_0002;
    let err = merge_artifacts(vec![(meta(0, 2), vec![plain(0)]), (other_spec, vec![plain(1)])])
        .unwrap_err();
    assert!(err.to_string().contains("--filter"), "got: {err}");

    // No shards at all.
    assert!(merge_artifacts(vec![]).is_err());
}

#[test]
fn truncated_and_corrupted_files_are_rejected() {
    let p = tmpfile("corrupt");
    write_artifact(&p, &meta(0, 2), &[plain(0), ornate(2)]).unwrap();
    let pristine = std::fs::read(&p).unwrap();

    // Truncation at any point of the frame must error, never panic.
    for cut in [0, 5, 23, pristine.len() / 2, pristine.len() - 1] {
        std::fs::write(&p, &pristine[..cut]).unwrap();
        assert!(read_artifact(&p).is_err(), "accepted {cut}-byte prefix");
    }

    // A single flipped payload byte trips the frame CRC.
    let mut bent = pristine.clone();
    let last = bent.len() - 3;
    bent[last] ^= 0x40;
    std::fs::write(&p, &bent).unwrap();
    assert!(read_artifact(&p).is_err(), "corrupted payload accepted");

    // Garbage that is not a frame at all.
    std::fs::write(&p, b"not a shard artifact").unwrap();
    assert!(read_artifact(&p).is_err());

    // And the pristine bytes still read fine (the writer is not at fault).
    std::fs::write(&p, &pristine).unwrap();
    assert!(read_artifact(&p).is_ok());
    std::fs::remove_file(&p).unwrap();
}
