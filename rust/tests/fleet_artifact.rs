//! Durable shard WALs: append→recover round-trips (including non-ASCII
//! mismatch notes and empty shards), merge idempotence and commutativity
//! across shard orders, torn-tail tolerance, and version hygiene — the
//! SDWL reader must refuse the retired `SDJL`/`SDSH` formats by name.

use std::time::Duration;

use sedar::campaign::shard::TaskOutcome;
use sedar::campaign::{CampaignApp, CampaignReport};
use sedar::config::{CollectiveImpl, Strategy};
use sedar::detect::ValidationMode;
use sedar::error::FaultClass;
use sedar::faultnet::NetFaultMode;
use sedar::fleet::snapshot::{merge_wals, read_wal};
use sedar::fleet::wal::{ShardMeta, Wal};
use sedar::recovery::ResumeFrom;

fn tmpfile(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "sedar-wal-{tag}-{}-{:?}.wal",
        std::process::id(),
        std::thread::current().id()
    ))
}

fn meta(index: u32, count: u32) -> ShardMeta {
    ShardMeta {
        seed: 42,
        shard_index: index,
        shard_count: count,
        total_tasks: 6,
        spec_hash: 0xF1E7_0001,
    }
}

/// An outcome exercising every optional field and non-ASCII text.
fn ornate(index: usize) -> TaskOutcome {
    TaskOutcome {
        index,
        scenario_id: 50,
        app: CampaignApp::Jacobi,
        strategy: Strategy::SysCkpt,
        collectives: CollectiveImpl::Native,
        validation: ValidationMode::Sha256,
        netfault: NetFaultMode::Mixed,
        faults: 3,
        completed: true,
        restarts: 2,
        injected: true,
        correct: Some(false),
        first_detection: Some((FaultClass::Fsc, "VALIDATE→rank0".into())),
        last_resume: Some(ResumeFrom::SysCkpt(2)),
        pass: false,
        mismatches: vec![
            "résultat faux: ≠ oracle".into(),
            "νote with emoji ✗ and cyrillic ошибка".into(),
        ],
        wall: Duration::from_millis(12),
        metrics: sedar::metrics::MetricsSnapshot {
            compare_ticks: 1,
            compare_bytes: 2,
            sync_ticks: 3,
            sync_events: 4,
            sys_ckpt_ticks: 5,
            sys_ckpt_bytes: 6,
            sys_ckpts: 7,
            user_ckpt_ticks: 8,
            user_ckpt_bytes: 9,
            user_ckpts: 10,
            exec_ticks: 11,
            execs: 12,
            rollback_ticks: 13,
            rollbacks: 14,
        },
    }
}

/// A minimal all-defaults outcome.
fn plain(index: usize) -> TaskOutcome {
    TaskOutcome {
        index,
        scenario_id: 1,
        app: CampaignApp::Matmul,
        strategy: Strategy::DetectOnly,
        collectives: CollectiveImpl::PointToPoint,
        validation: ValidationMode::Full,
        netfault: NetFaultMode::None,
        faults: 1,
        completed: true,
        restarts: 0,
        injected: true,
        correct: Some(true),
        first_detection: None,
        last_resume: None,
        pass: true,
        mismatches: vec![],
        wall: Duration::ZERO,
        metrics: Default::default(),
    }
}

/// Write a complete shard WAL (append every outcome, then finalize).
fn write_wal(path: &std::path::Path, m: &ShardMeta, outcomes: &[TaskOutcome]) {
    let _ = std::fs::remove_file(path);
    let (mut w, recovered) = Wal::open(path, m).unwrap();
    assert!(recovered.is_empty(), "fresh WAL recovered outcomes");
    for o in outcomes {
        w.append(o).unwrap();
    }
    w.finalize().unwrap();
}

#[test]
fn wal_roundtrip_preserves_everything() {
    let p = tmpfile("roundtrip");
    let outcomes = vec![plain(0), ornate(2), plain(4)];
    write_wal(&p, &meta(0, 2), &outcomes);
    let (m, back) = read_wal(&p).unwrap();
    assert_eq!(m, meta(0, 2));
    assert_eq!(back.len(), 3);
    // Field-for-field equality via Debug (TaskOutcome has no PartialEq).
    for (a, b) in outcomes.iter().zip(&back) {
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }
    std::fs::remove_file(&p).unwrap();
}

#[test]
fn empty_shard_roundtrips() {
    let p = tmpfile("empty");
    write_wal(&p, &meta(1, 2), &[]);
    let (m, back) = read_wal(&p).unwrap();
    assert_eq!(m.shard_index, 1);
    assert!(back.is_empty());
    std::fs::remove_file(&p).unwrap();
}

#[test]
fn merge_is_idempotent_and_commutative_over_shard_order() {
    let a = (meta(0, 2), vec![plain(0), ornate(2), plain(4)]);
    let b = (meta(1, 2), vec![plain(1), plain(3), plain(5)]);
    let (seed_ab, total_ab, ab) = merge_wals(vec![a.clone(), b.clone()]).unwrap();
    let (seed_ba, total_ba, ba) = merge_wals(vec![b.clone(), a.clone()]).unwrap();
    assert_eq!((seed_ab, total_ab), (seed_ba, total_ba));
    assert_eq!(
        CampaignReport::new(seed_ab, ab).deterministic_report(),
        CampaignReport::new(seed_ba, ba).deterministic_report(),
        "merge must be commutative over shard order"
    );
    // Idempotent: merging the merged set with nothing new changes nothing.
    let (_, _, once) = merge_wals(vec![a.clone(), b.clone()]).unwrap();
    let (_, _, again) = merge_wals(vec![(meta(0, 1), once.clone())]).unwrap();
    assert_eq!(format!("{once:?}"), format!("{again:?}"));
}

#[test]
fn merge_rejects_overlap_seed_and_spec_drift() {
    // Two *different* shards claiming one task index: rejected when the
    // union is materialized. (Feeding the *same* shard twice is idempotent
    // by design — the live merger replaces that shard's contribution.)
    let err = merge_wals(vec![
        (meta(0, 2), vec![plain(0)]),
        (meta(1, 2), vec![plain(0)]),
    ])
    .unwrap_err();
    assert!(err.to_string().contains("duplicate task index"), "{err}");

    // Mismatched seeds.
    let mut other_seed = meta(1, 2);
    other_seed.seed = 43;
    assert!(
        merge_wals(vec![(meta(0, 2), vec![plain(0)]), (other_seed, vec![plain(1)])]).is_err()
    );

    // Mismatched filtered-sweep widths.
    let mut other_total = meta(1, 2);
    other_total.total_tasks = 7;
    assert!(
        merge_wals(vec![(meta(0, 2), vec![plain(0)]), (other_total, vec![plain(1)])]).is_err()
    );

    // Same seed and width, different filter set (spec fingerprint drift —
    // e.g. scenario=1-12 vs scenario=13-24 both yield 12 tasks).
    let mut other_spec = meta(1, 2);
    other_spec.spec_hash = 0xF1E7_0002;
    let err = merge_wals(vec![(meta(0, 2), vec![plain(0)]), (other_spec, vec![plain(1)])])
        .unwrap_err();
    assert!(err.to_string().contains("--filter"), "got: {err}");

    // No shards at all.
    assert!(merge_wals(vec![]).is_err());
}

#[test]
fn same_shard_ingested_twice_replaces_instead_of_erroring() {
    // The streaming supervisor re-reads a live WAL every time it grows; the
    // union must absorb the re-read, not reject it as an overlap.
    let early = (meta(0, 1), vec![plain(0), plain(1)]);
    let later = (meta(0, 1), vec![plain(0), plain(1), ornate(2)]);
    let (_, _, merged) = merge_wals(vec![early, later]).unwrap();
    assert_eq!(merged.len(), 3, "later read must replace the earlier one");
}

#[test]
fn torn_tail_drops_records_but_never_errors() {
    // A reader racing the writer (or a crash mid-append) sees a torn last
    // frame: the valid prefix must read cleanly, the tail silently dropped.
    let p = tmpfile("torn");
    write_wal(&p, &meta(0, 2), &[plain(0), ornate(2)]);
    let pristine = std::fs::read(&p).unwrap();
    let (_, full) = read_wal(&p).unwrap();
    assert_eq!(full.len(), 2);

    // Chop anywhere past the header: the read succeeds with a (possibly
    // shorter) prefix of the outcomes, never a panic or error.
    for cut in [48, 53, pristine.len() / 2, pristine.len() - 1] {
        std::fs::write(&p, &pristine[..cut]).unwrap();
        let (m, back) = read_wal(&p).unwrap();
        assert_eq!(m, meta(0, 2));
        assert!(back.len() <= 2, "cut at {cut} invented outcomes");
    }

    // Chopping *into the header* is a hard error — the file's identity is
    // gone, so resume cannot trust it.
    for cut in [0, 5, 23] {
        std::fs::write(&p, &pristine[..cut]).unwrap();
        assert!(read_wal(&p).is_err(), "accepted {cut}-byte header prefix");
    }

    // A flipped payload byte trips that record's CRC and ends the valid
    // prefix there. Bending the *first* outcome record (the header is the
    // first 48 bytes) leaves nothing recoverable…
    let mut bent = pristine.clone();
    bent[60] ^= 0x40;
    std::fs::write(&p, &bent).unwrap();
    let (_, back) = read_wal(&p).unwrap();
    assert!(back.is_empty(), "corrupted record accepted");

    // …while bending the trailing compaction snapshot only loses the
    // snapshot: the reader falls back to the intact records before it.
    let mut bent = pristine.clone();
    let last = bent.len() - 3;
    bent[last] ^= 0x40;
    std::fs::write(&p, &bent).unwrap();
    let (_, back) = read_wal(&p).unwrap();
    assert_eq!(back.len(), 2, "records before a torn snapshot must survive");

    // And the pristine bytes still read fine (the writer is not at fault).
    std::fs::write(&p, &pristine).unwrap();
    let (_, back) = read_wal(&p).unwrap();
    assert_eq!(back.len(), 2);
    std::fs::remove_file(&p).unwrap();
}

#[test]
fn retired_formats_are_refused_by_name() {
    // Version hygiene: the SDWL v1 reader names both the format it found
    // and the format it reads, and never modifies the refused file.
    let p = tmpfile("legacy");

    // A v4-era resume journal (SDJL magic under the shared framing).
    let mut body = Vec::new();
    body.extend_from_slice(b"SDJL");
    body.extend_from_slice(&4u32.to_le_bytes());
    body.extend_from_slice(&[0u8; 32]);
    let mut framed = Vec::new();
    sedar::util::frame::frame(&body, &mut framed);
    std::fs::write(&p, &framed).unwrap();
    let err = read_wal(&p).unwrap_err().to_string();
    assert!(err.contains("SDJL"), "journal not named: {err}");
    assert!(err.contains("SDWL"), "replacement not named: {err}");
    assert_eq!(std::fs::read(&p).unwrap(), framed, "refused file modified");

    // A pre-SDWL shard artifact (an SDSH payload inside an SDCK container
    // frame — the reader recognizes the container prefix).
    let relic = b"SDCK pretending to hold an SDSH artifact".to_vec();
    std::fs::write(&p, &relic).unwrap();
    let err = read_wal(&p).unwrap_err().to_string();
    assert!(
        err.contains("SDSH") || err.contains("SDCK"),
        "artifact not named: {err}"
    );
    assert!(err.contains("SDWL"), "replacement not named: {err}");
    assert_eq!(std::fs::read(&p).unwrap(), relic, "refused file modified");

    // Garbage that is no known format at all.
    std::fs::write(&p, b"not a shard WAL").unwrap();
    assert!(read_wal(&p).is_err());

    // Resume (Wal::open) applies the same hygiene: it must not truncate or
    // overwrite a file it did not positively identify as a WAL.
    std::fs::write(&p, &framed).unwrap();
    let err = Wal::open(&p, &meta(0, 2)).unwrap_err().to_string();
    assert!(err.contains("SDJL") && err.contains("SDWL"), "got: {err}");
    assert_eq!(std::fs::read(&p).unwrap(), framed, "refused file modified");
    std::fs::remove_file(&p).unwrap();
}
