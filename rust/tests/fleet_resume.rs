//! Resume semantics: a shard killed mid-sweep re-runs from its WAL, skips
//! every finished task, and still renders the byte-identical report. The
//! kill is simulated by pre-populating a WAL with a prefix of the outcomes
//! — exactly the on-disk state a real kill leaves behind (records are
//! synced as tasks finish, and the torn-tail / torn-snapshot handling is
//! unit-tested in `fleet::wal`).

use sedar::campaign::{build_tasks, sweep_fingerprint, CampaignReport, CampaignSpec};
use sedar::config::RunConfig;
use sedar::fleet::wal::{ShardMeta, Wal};
use sedar::fleet::{run_shard, FleetOptions};

/// One scenario across every app × strategy × collectives mode: 18 tasks
/// — enough to split into "finished before the kill" and "still to do",
/// small enough to run twice in this suite.
fn spec(tag: &str) -> CampaignSpec {
    let mut spec = CampaignSpec::new(77);
    spec.apply_filter("scenario=2").unwrap();
    spec.jobs = 2;
    let toe_timeout = spec.base.toe_timeout;
    let mut base = RunConfig::for_tests(tag);
    base.run_dir = std::env::temp_dir().join(format!(
        "sedar-fleet-resume-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    base.toe_timeout = toe_timeout;
    spec.base = base;
    spec
}

fn tmpfile(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "sedar-fleet-resume-{tag}-{}-{:?}.wal",
        std::process::id(),
        std::thread::current().id()
    ))
}

#[test]
fn wal_resume_skips_finished_tasks_and_reproduces_the_report() {
    // Reference: an uninterrupted run writing its WAL.
    let spec_a = spec("full");
    let wal_a = tmpfile("wal-full");
    let _ = std::fs::remove_file(&wal_a);
    let run_a = run_shard(
        &spec_a,
        &FleetOptions {
            wal_path: Some(wal_a.clone()),
            ..FleetOptions::default()
        },
    )
    .unwrap();
    assert_eq!(run_a.owned, 18);
    assert_eq!(run_a.resumed, 0);
    assert_eq!(run_a.executed, 18);
    let report_a = CampaignReport::new(spec_a.seed, run_a.outcomes.clone());
    let _ = std::fs::remove_dir_all(&spec_a.base.run_dir);

    // An idempotent re-run over the completed WAL executes nothing and
    // renders the same bytes — and appends nothing either (the no-op
    // resume must leave the file byte-identical).
    let before = std::fs::read(&wal_a).unwrap();
    let spec_b = spec("idempotent");
    let run_b = run_shard(
        &spec_b,
        &FleetOptions {
            wal_path: Some(wal_a.clone()),
            ..FleetOptions::default()
        },
    )
    .unwrap();
    assert_eq!(run_b.resumed, 18);
    assert_eq!(run_b.executed, 0, "a complete WAL re-executes nothing");
    assert_eq!(
        CampaignReport::new(spec_b.seed, run_b.outcomes).deterministic_report(),
        report_a.deterministic_report()
    );
    assert_eq!(
        std::fs::read(&wal_a).unwrap(),
        before,
        "no-op resume must not grow the WAL"
    );
    let _ = std::fs::remove_dir_all(&spec_b.base.run_dir);

    // Simulate the kill: a WAL holding only the first 4 outcomes. The
    // header must carry the sweep's real fingerprint or run_shard will
    // (correctly) refuse the WAL.
    let wal_c = tmpfile("wal-killed");
    let _ = std::fs::remove_file(&wal_c);
    let spec_for_meta = spec("meta");
    let meta = ShardMeta {
        seed: 77,
        shard_index: 0,
        shard_count: 1,
        total_tasks: 18,
        spec_hash: sweep_fingerprint(77, &build_tasks(&spec_for_meta)),
    };
    {
        let (mut w, recovered) = Wal::open(&wal_c, &meta).unwrap();
        assert!(recovered.is_empty());
        for o in run_a.outcomes.iter().take(4) {
            w.append(o).unwrap();
        }
        // No finalize: a killed process never reaches clean shutdown.
    }

    // The re-run resumes: only the 14 unfinished tasks execute, and the
    // final report is byte-identical to the uninterrupted run's.
    let spec_c = spec("resumed");
    let run_c = run_shard(
        &spec_c,
        &FleetOptions {
            wal_path: Some(wal_c.clone()),
            ..FleetOptions::default()
        },
    )
    .unwrap();
    assert_eq!(run_c.resumed, 4);
    assert_eq!(run_c.executed, 14, "WAL-recorded tasks must not re-execute");
    assert_eq!(
        CampaignReport::new(spec_c.seed, run_c.outcomes).deterministic_report(),
        report_a.deterministic_report(),
        "resumed run must render the byte-identical report"
    );
    let _ = std::fs::remove_dir_all(&spec_c.base.run_dir);

    // A WAL from a different sweep is refused outright.
    let mut spec_d = spec("wrong-seed");
    spec_d.seed = 78;
    let err = run_shard(
        &spec_d,
        &FleetOptions {
            wal_path: Some(wal_c.clone()),
            ..FleetOptions::default()
        },
    )
    .unwrap_err();
    assert!(err.to_string().contains("different sweep"), "got: {err}");
    let _ = std::fs::remove_dir_all(&spec_d.base.run_dir);

    let _ = std::fs::remove_file(wal_a);
    let _ = std::fs::remove_file(wal_c);
}

#[test]
fn resume_refuses_a_legacy_journal_by_name() {
    // Version hygiene at the resume entry point: pointing --wal at a
    // v4-era SDJL resume journal must fail naming both formats, and the
    // refused file must not be truncated or overwritten.
    let p = tmpfile("legacy-journal");
    let mut body = Vec::new();
    body.extend_from_slice(b"SDJL");
    body.extend_from_slice(&4u32.to_le_bytes());
    body.extend_from_slice(&[0u8; 32]);
    let mut framed = Vec::new();
    sedar::util::frame::frame(&body, &mut framed);
    std::fs::write(&p, &framed).unwrap();

    let spec_e = spec("legacy");
    let err = run_shard(
        &spec_e,
        &FleetOptions {
            wal_path: Some(p.clone()),
            ..FleetOptions::default()
        },
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("SDJL"), "old format not named: {err}");
    assert!(err.contains("SDWL"), "new format not named: {err}");
    assert_eq!(std::fs::read(&p).unwrap(), framed, "refused file modified");
    let _ = std::fs::remove_dir_all(&spec_e.base.run_dir);
    std::fs::remove_file(&p).unwrap();
}
