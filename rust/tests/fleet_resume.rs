//! Resume semantics: a shard killed mid-sweep re-runs from its journal,
//! skips every finished task, and still renders the byte-identical report.
//! The kill is simulated by pre-populating a journal with a prefix of the
//! outcomes — exactly the on-disk state a real kill leaves behind (the
//! journal is synced per record, and its torn-tail handling is unit-tested
//! in `fleet::journal`).

use sedar::campaign::{build_tasks, sweep_fingerprint, CampaignReport, CampaignSpec};
use sedar::config::RunConfig;
use sedar::fleet::artifact::ShardMeta;
use sedar::fleet::journal::Journal;
use sedar::fleet::{run_shard, FleetOptions};

/// One scenario across every app × strategy × collectives mode: 18 tasks
/// — enough to split into "finished before the kill" and "still to do",
/// small enough to run twice in this suite.
fn spec(tag: &str) -> CampaignSpec {
    let mut spec = CampaignSpec::new(77);
    spec.apply_filter("scenario=2").unwrap();
    spec.jobs = 2;
    let toe_timeout = spec.base.toe_timeout;
    let mut base = RunConfig::for_tests(tag);
    base.run_dir = std::env::temp_dir().join(format!(
        "sedar-fleet-resume-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    base.toe_timeout = toe_timeout;
    spec.base = base;
    spec
}

fn tmpfile(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "sedar-fleet-resume-{tag}-{}-{:?}.bin",
        std::process::id(),
        std::thread::current().id()
    ))
}

#[test]
fn journal_resume_skips_finished_tasks_and_reproduces_the_report() {
    // Reference: an uninterrupted, journaled run.
    let spec_a = spec("full");
    let journal_a = tmpfile("journal-full");
    let _ = std::fs::remove_file(&journal_a);
    let run_a = run_shard(
        &spec_a,
        &FleetOptions {
            journal_path: Some(journal_a.clone()),
            ..FleetOptions::default()
        },
    )
    .unwrap();
    assert_eq!(run_a.owned, 18);
    assert_eq!(run_a.resumed, 0);
    assert_eq!(run_a.executed, 18);
    let report_a = CampaignReport::new(spec_a.seed, run_a.outcomes.clone());
    let _ = std::fs::remove_dir_all(&spec_a.base.run_dir);

    // An idempotent re-run over the completed journal executes nothing and
    // renders the same bytes.
    let spec_b = spec("idempotent");
    let run_b = run_shard(
        &spec_b,
        &FleetOptions {
            journal_path: Some(journal_a.clone()),
            ..FleetOptions::default()
        },
    )
    .unwrap();
    assert_eq!(run_b.resumed, 18);
    assert_eq!(run_b.executed, 0, "a complete journal re-executes nothing");
    assert_eq!(
        CampaignReport::new(spec_b.seed, run_b.outcomes).deterministic_report(),
        report_a.deterministic_report()
    );
    let _ = std::fs::remove_dir_all(&spec_b.base.run_dir);

    // Simulate the kill: a journal holding only the first 4 outcomes. The
    // meta must carry the sweep's real fingerprint or run_shard will
    // (correctly) refuse the journal.
    let journal_c = tmpfile("journal-killed");
    let _ = std::fs::remove_file(&journal_c);
    let spec_for_meta = spec("meta");
    let meta = ShardMeta {
        seed: 77,
        shard_index: 0,
        shard_count: 1,
        total_tasks: 18,
        spec_hash: sweep_fingerprint(77, &build_tasks(&spec_for_meta)),
    };
    {
        let (mut j, recovered) = Journal::open(&journal_c, &meta).unwrap();
        assert!(recovered.is_empty());
        for o in run_a.outcomes.iter().take(4) {
            j.append(o).unwrap();
        }
    }

    // The re-run resumes: only the 14 unfinished tasks execute, and the
    // final report is byte-identical to the uninterrupted run's.
    let spec_c = spec("resumed");
    let run_c = run_shard(
        &spec_c,
        &FleetOptions {
            journal_path: Some(journal_c.clone()),
            ..FleetOptions::default()
        },
    )
    .unwrap();
    assert_eq!(run_c.resumed, 4);
    assert_eq!(run_c.executed, 14, "journaled tasks must not re-execute");
    assert_eq!(
        CampaignReport::new(spec_c.seed, run_c.outcomes).deterministic_report(),
        report_a.deterministic_report(),
        "resumed run must render the byte-identical report"
    );
    let _ = std::fs::remove_dir_all(&spec_c.base.run_dir);

    // A journal from a different sweep is refused outright.
    let mut spec_d = spec("wrong-seed");
    spec_d.seed = 78;
    let err = run_shard(
        &spec_d,
        &FleetOptions {
            journal_path: Some(journal_c.clone()),
            ..FleetOptions::default()
        },
    )
    .unwrap_err();
    assert!(
        err.to_string().contains("different sweep"),
        "got: {err}"
    );
    let _ = std::fs::remove_dir_all(&spec_d.base.run_dir);

    let _ = std::fs::remove_file(journal_a);
    let _ = std::fs::remove_file(journal_c);
}
