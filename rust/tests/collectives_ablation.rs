//! Ablation of §4.2's collectives observation:
//!
//! > "in collective communications, the sender process also participates
//! > […] If our test application just uses collective operations, the
//! > corrupted data gets transmitted and hence it is validated. In this
//! > way, only TDC scenarios remain and FSC scenarios should not be
//! > present any longer."
//!
//! We run the *same* master-local corruption under both collective
//! implementations: with point-to-point collectives it surfaces late as an
//! FSC at VALIDATE; with native (optimized) collectives the root's own
//! contribution is validated inside the collective, so the same fault is a
//! TDC caught at GATHER — earlier, with a shorter rollback.

use std::sync::Arc;

use sedar::apps::matmul::{phases, MatmulApp};
use sedar::apps::spec::AppSpec;
use sedar::config::{CollectiveImpl, RunConfig, Strategy};
use sedar::coordinator::SedarRun;
use sedar::error::FaultClass;
use sedar::inject::{InjectKind, InjectPoint, InjectionSpec};

/// Corrupt the master's OWN result chunk right after compute — data that a
/// p2p gather never transmits.
fn master_local_corruption() -> InjectionSpec {
    InjectionSpec {
        name: "master-cchunk".into(),
        point: InjectPoint::BeforePhase(phases::GATHER),
        rank: 0,
        replica: 1,
        kind: InjectKind::BitFlip {
            var: "C_chunk".into(),
            elem: 4,
            bit: 30,
        },
    }
}

fn run_with(collectives: CollectiveImpl, tag: &str) -> sedar::coordinator::RunOutcome {
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(64, 4));
    let mut cfg = RunConfig::for_tests(tag);
    cfg.strategy = Strategy::SysCkpt;
    cfg.collectives = collectives;
    SedarRun::new(app, cfg, Some(master_local_corruption()))
        .run()
        .unwrap()
}

#[test]
fn p2p_collectives_leave_fsc_scenarios() {
    let outcome = run_with(CollectiveImpl::PointToPoint, "abl-p2p");
    assert_eq!(outcome.result_correct, Some(true));
    let first = &outcome.detections[0];
    // Not transmitted → detected only by the final-result comparison.
    assert_eq!(first.class, FaultClass::Fsc);
    assert_eq!(first.site, "VALIDATE");
    // CK3 captured the corrupt C → dirty → two rollbacks.
    assert_eq!(outcome.restarts, 2);
}

#[test]
fn native_collectives_turn_fsc_into_tdc() {
    let outcome = run_with(CollectiveImpl::Native, "abl-native");
    assert_eq!(outcome.result_correct, Some(true));
    let first = &outcome.detections[0];
    // The gather validates the root's own contribution too → caught at the
    // collective itself, before the dirty checkpoint even exists.
    assert_eq!(first.class, FaultClass::Tdc);
    assert_eq!(first.site, "GATHER");
    // Detection latency shrank: CK2 is the last stored ckpt and it is
    // clean → a single rollback.
    assert_eq!(outcome.restarts, 1);
}

#[test]
fn both_modes_agree_on_fault_free_results() {
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(64, 4));
    let mut results = Vec::new();
    for (mode, tag) in [
        (CollectiveImpl::PointToPoint, "abl-clean-p2p"),
        (CollectiveImpl::Native, "abl-clean-nat"),
    ] {
        let mut cfg = RunConfig::for_tests(tag);
        cfg.strategy = Strategy::UserCkpt;
        cfg.collectives = mode;
        let outcome = SedarRun::new(app.clone(), cfg, None).run().unwrap();
        assert_eq!(outcome.result_correct, Some(true));
        results.push(outcome.attempts);
    }
    assert_eq!(results, vec![1, 1]);
}

#[test]
fn native_mode_full_campaign_smoke() {
    // A slice of the workfault under native collectives: TDC rows keep
    // their predictions (transmission-validated either way); LE rows stay
    // latent. FSC rows intentionally differ — `run_scenario` now grades
    // against the native oracle (`workfault::predict_native`), and the
    // full both-mode catalog runs in `rust/tests/campaign64.rs` and the
    // equivalence suite; this smoke keeps the unchanged classes honest.
    let app = MatmulApp::new(64, 4);
    let mut cfg = RunConfig::for_tests("abl-campaign");
    cfg.collectives = CollectiveImpl::Native;
    for sc in sedar::workfault::catalog(&app) {
        if sc.effect == FaultClass::Tdc || sc.effect == FaultClass::Le {
            let r = sedar::workfault::run_scenario(&app, &sc, &cfg).unwrap();
            assert!(
                r.pass,
                "scenario {} under native collectives: {:?}",
                sc.id, r.mismatches
            );
        }
    }
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
}
