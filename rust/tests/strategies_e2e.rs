//! End-to-end runs: every application × every strategy, fault-free and
//! with injected faults, p2p and native collectives.

use std::sync::Arc;

use sedar::apps::spec::AppSpec;
use sedar::apps::{JacobiApp, MatmulApp, SwApp};
use sedar::config::{CollectiveImpl, RunConfig, Strategy};
use sedar::coordinator::SedarRun;
use sedar::error::FaultClass;
use sedar::inject::{InjectKind, InjectPoint, InjectionSpec};

fn cfg(tag: &str, strategy: Strategy) -> RunConfig {
    let mut c = RunConfig::for_tests(tag);
    c.strategy = strategy;
    c
}

fn apps() -> Vec<Arc<dyn AppSpec>> {
    vec![
        Arc::new(MatmulApp::new(64, 4)),
        Arc::new(JacobiApp::new(64, 4, 6, 3)),
        Arc::new(SwApp::new(64, 4, 16, 2)),
    ]
}

#[test]
fn every_app_every_strategy_fault_free() {
    for app in apps() {
        for strategy in [
            Strategy::Baseline,
            Strategy::DetectOnly,
            Strategy::SysCkpt,
            Strategy::UserCkpt,
        ] {
            let tag = format!("e2e-{}-{}", app.name(), strategy.label());
            let outcome = SedarRun::new(app.clone(), cfg(&tag, strategy), None)
                .run()
                .unwrap();
            assert!(outcome.completed, "{tag}: did not complete");
            assert_eq!(outcome.result_correct, Some(true), "{tag}: wrong result");
            assert_eq!(outcome.restarts, 0, "{tag}: unexpected restarts");
            assert!(outcome.detections.is_empty(), "{tag}: spurious detection");
        }
    }
}

#[test]
fn native_collectives_fault_free_all_apps() {
    for app in apps() {
        let mut c = cfg(&format!("e2e-native-{}", app.name()), Strategy::SysCkpt);
        c.collectives = CollectiveImpl::Native;
        let outcome = SedarRun::new(app.clone(), c, None).run().unwrap();
        assert_eq!(outcome.result_correct, Some(true), "{}", app.name());
    }
}

fn matmul_fsc_spec() -> InjectionSpec {
    // C(M) corrupted between GATHER and CK3 (the paper's Scenario 50).
    InjectionSpec {
        name: "fsc-c".into(),
        point: InjectPoint::BeforePhase(sedar::apps::matmul::phases::CK3),
        rank: 0,
        replica: 1,
        kind: InjectKind::BitFlip {
            var: "C".into(),
            elem: 11,
            bit: 30,
        },
    }
}

#[test]
fn detect_only_safe_stops_then_relaunches() {
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(64, 4));
    let outcome = SedarRun::new(
        app,
        cfg("detect-fsc", Strategy::DetectOnly),
        Some(matmul_fsc_spec()),
    )
    .run()
    .unwrap();
    assert!(outcome.completed);
    assert_eq!(outcome.result_correct, Some(true));
    assert_eq!(outcome.restarts, 1); // one relaunch from scratch
    assert_eq!(outcome.detections.len(), 1);
    assert_eq!(outcome.detections[0].class, FaultClass::Fsc);
    assert_eq!(outcome.detections[0].site, "VALIDATE");
    assert!(matches!(
        outcome.resume_history[0],
        sedar::recovery::ResumeFrom::Scratch
    ));
}

#[test]
fn sysckpt_walks_dirty_checkpoint() {
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(64, 4));
    let outcome = SedarRun::new(
        app,
        cfg("sys-fsc", Strategy::SysCkpt),
        Some(matmul_fsc_spec()),
    )
    .run()
    .unwrap();
    // Figure 2(b): CK3 dirty → 2 rollbacks, recovery from CK2.
    assert_eq!(outcome.restarts, 2);
    assert_eq!(outcome.result_correct, Some(true));
    assert_eq!(outcome.detections.len(), 2);
    assert!(matches!(
        outcome.resume_history.last().unwrap(),
        sedar::recovery::ResumeFrom::SysCkpt(2)
    ));
}

#[test]
fn userckpt_catches_corruption_at_checkpoint_validation() {
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(64, 4));
    let outcome = SedarRun::new(
        app,
        cfg("user-fsc", Strategy::UserCkpt),
        Some(matmul_fsc_spec()),
    )
    .run()
    .unwrap();
    // Algorithm 2: the corrupted candidate is caught AT CK3, never stored;
    // exactly one rollback to the last valid checkpoint (CK2).
    assert_eq!(outcome.restarts, 1);
    assert_eq!(outcome.result_correct, Some(true));
    assert_eq!(outcome.detections[0].class, FaultClass::CkptCorrupt);
    assert_eq!(outcome.detections[0].site, "CK3");
    assert!(matches!(
        outcome.resume_history[0],
        sedar::recovery::ResumeFrom::UserCkpt(2)
    ));
}

#[test]
fn baseline_votes_out_a_corrupted_instance() {
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(64, 4));
    // Corrupt instance 1's C near the end: the two instances disagree at
    // the final comparison, the third run + vote picks the clean pair.
    let spec = InjectionSpec {
        replica: 1, // instance 1
        ..matmul_fsc_spec()
    };
    let outcome = SedarRun::new(app, cfg("baseline-vote", Strategy::Baseline), Some(spec))
        .run()
        .unwrap();
    assert!(outcome.completed);
    assert_eq!(outcome.attempts, 3); // two instances + tie-breaker
    assert_eq!(outcome.result_correct, Some(true));
}

#[test]
fn jacobi_tdc_detected_at_next_halo_exchange() {
    let app = JacobiApp::new(64, 4, 6, 3);
    let phase = app.cursor_of("ITER4");
    let spec = InjectionSpec {
        name: "jacobi-halo".into(),
        point: InjectPoint::BeforePhase(phase),
        rank: 1,
        replica: 1,
        kind: InjectKind::BitFlip {
            var: "grid".into(),
            elem: 3, // row 0 → goes out with the next top-halo send
            bit: 30,
        },
    };
    let outcome = SedarRun::new(
        Arc::new(app),
        cfg("jacobi-tdc", Strategy::SysCkpt),
        Some(spec),
    )
    .run()
    .unwrap();
    assert_eq!(outcome.result_correct, Some(true));
    assert_eq!(outcome.detections[0].class, FaultClass::Tdc);
    assert_eq!(outcome.detections[0].site, "ITER4");
    assert_eq!(outcome.restarts, 1); // CK0 (after ITER2) is clean
}

#[test]
fn sw_frontier_corruption_detected_downstream_send() {
    let app = SwApp::new(64, 4, 16, 2);
    let phase = app.cursor_of("BLOCK2");
    let spec = InjectionSpec {
        name: "sw-front".into(),
        point: InjectPoint::BeforePhase(phase),
        rank: 1,
        replica: 1,
        kind: InjectKind::BitFlip {
            // The band's last-column carry: its value at block entry is
            // copied verbatim into frontier[0] of the outgoing message, so
            // the corruption is guaranteed to reach the downstream compare
            // (an interior element can be absorbed by the DP's max/clamp).
            var: "prev_row".into(),
            elem: 15, // band_width - 1
            bit: 30,
        },
    };
    let outcome = SedarRun::new(
        Arc::new(app),
        cfg("sw-tdc", Strategy::SysCkpt),
        Some(spec),
    )
    .run()
    .unwrap();
    assert_eq!(outcome.result_correct, Some(true));
    assert_eq!(outcome.detections[0].class, FaultClass::Tdc);
    assert_eq!(outcome.detections[0].site, "BLOCK2");
}

#[test]
fn exhausted_attempts_give_up_cleanly() {
    // A fault is detected on every attempt when max_attempts is too small
    // to reach a clean re-execution: the coordinator must give up with a
    // truthful outcome rather than loop or panic.
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(64, 4));
    let mut cfg = cfg("give-up", Strategy::SysCkpt);
    cfg.max_attempts = 1; // detection on attempt 1 → no budget to recover
    let outcome = SedarRun::new(app, cfg, Some(matmul_fsc_spec()))
        .run()
        .unwrap();
    assert!(!outcome.completed);
    assert_eq!(outcome.attempts, 1);
    assert_eq!(outcome.detections.len(), 1);
    assert_eq!(outcome.result_correct, None);
    assert!(outcome.summary().contains("GAVE UP"));
}

#[test]
fn sha256_validation_mode_detects_too() {
    // The RedMPI-style hashed validation catches the same divergence.
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(64, 4));
    let mut cfg = cfg("sha-mode", Strategy::SysCkpt);
    cfg.validation = sedar::detect::ValidationMode::Sha256;
    let outcome = SedarRun::new(app, cfg, Some(matmul_fsc_spec()))
        .run()
        .unwrap();
    assert_eq!(outcome.result_correct, Some(true));
    assert_eq!(outcome.detections[0].class, FaultClass::Fsc);
    assert_eq!(outcome.restarts, 2);
}

#[test]
fn run_summary_is_informative() {
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(64, 4));
    let outcome = SedarRun::new(app, cfg("summary", Strategy::SysCkpt), Some(matmul_fsc_spec()))
        .run()
        .unwrap();
    let s = outcome.summary();
    assert!(s.contains("matmul"));
    assert!(s.contains("sys-ckpt"));
    assert!(s.contains("FSC@VALIDATE"));
    assert!(s.contains("CORRECT"));
    // Figure-3-style trace exists and mentions the key events.
    assert!(outcome.trace_dump.contains("INJECTED"));
    assert!(outcome.trace_dump.contains("system checkpoint #3 stored"));
    assert!(outcome.trace_dump.contains("FAULT FSC detected at VALIDATE"));
    assert!(outcome.trace_dump.contains("resume from sys-ck2"));
}
