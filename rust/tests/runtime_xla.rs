//! PJRT runtime integration: load the AOT HLO-text artifacts, execute them
//! from rust, and check the numerics against the pure-rust fallbacks —
//! the cross-layer contract (L1 Pallas == L2 jnp == L3 rust).
//!
//! These tests are `#[ignore]`d by default: they need the AOT artifacts
//! (`make artifacts`) *and* a binary built with the `pjrt` feature (the
//! external `xla` binding is not in the offline dependency set). Run them
//! with `cargo test --features pjrt -- --ignored`. Even when invoked, they
//! self-skip (not fail) if `artifacts/` is absent.

use sedar::apps::oracle;
use sedar::runtime::Engine;
use sedar::state::Var;
use sedar::util::prng::SplitMix64;

fn engine() -> Option<Engine> {
    let dir = Engine::default_artifact_dir();
    if !Engine::artifacts_available(&dir) {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Engine::start(&dir).expect("engine starts"))
}

fn rand_f32(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    let mut v = vec![0f32; n];
    rng.fill_f32(&mut v);
    v
}

#[test]
#[ignore = "requires PJRT artifacts + the pjrt feature; see module docs"]
fn matmul_artifact_matches_rust_oracle() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let (r, n) = (4usize, 64usize);
    let a = rand_f32(1, r * n);
    let b = rand_f32(2, n * n);
    let out = h
        .execute(
            "matmul_r4_n64",
            vec![Var::f32(&[r, n], a.clone()), Var::f32(&[n, n], b.clone())],
        )
        .unwrap();
    assert_eq!(out.len(), 1);
    let got = out[0].buf.as_f32().unwrap();
    let want = oracle::matmul_seq(&a, &b, r, n, n);
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() <= 1e-3_f32.max(w.abs() * 1e-5), "{g} vs {w}");
    }
}

#[test]
#[ignore = "requires PJRT artifacts + the pjrt feature; see module docs"]
fn jacobi_artifact_matches_rust_stencil() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let (rows, n) = (16usize, 64usize);
    let padded = rand_f32(3, (rows + 2) * n);
    let out = h
        .execute("jacobi_r16_n64", vec![Var::f32(&[rows + 2, n], padded.clone())])
        .unwrap();
    let got = out[0].buf.as_f32().unwrap();
    // The rust fallback stencil from apps::jacobi (inline here).
    for i in 0..rows {
        let pi = i + 1;
        for j in 0..n {
            let left = if j > 0 { padded[pi * n + j - 1] } else { 0.0 };
            let right = if j < n - 1 { padded[pi * n + j + 1] } else { 0.0 };
            let want =
                0.25 * (padded[(pi - 1) * n + j] + padded[(pi + 1) * n + j] + left + right);
            let g = got[i * n + j];
            assert!((g - want).abs() < 1e-5, "({i},{j}): {g} vs {want}");
        }
    }
}

#[test]
#[ignore = "requires PJRT artifacts + the pjrt feature; see module docs"]
fn sw_artifact_matches_rust_dp_block() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let (br, bw) = (16usize, 16usize);
    let mut rng = SplitMix64::new(4);
    let s1: Vec<f32> = (0..br).map(|_| rng.below(4) as f32).collect();
    let s2: Vec<f32> = (0..bw).map(|_| rng.below(4) as f32).collect();
    let prev = vec![0f32; bw];
    let left = vec![0f32; br + 1];
    let out = h
        .execute(
            "sw_b16_w16",
            vec![
                Var::f32(&[br], s1.clone()),
                Var::f32(&[bw], s2.clone()),
                Var::f32(&[bw], prev.clone()),
                Var::f32(&[br + 1], left.clone()),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 3);
    // Rust scalar DP (the SwApp fallback, inlined).
    let mut prev_r = prev.clone();
    let mut frontier = vec![0f32; br + 1];
    let mut best = 0f32;
    let mut cur = vec![0f32; bw];
    for i in 0..br {
        for j in 0..bw {
            let s = if s1[i] == s2[j] { 2.0 } else { -1.0 };
            let diag = if j == 0 { left[i] } else { prev_r[j - 1] };
            let up = prev_r[j];
            let lf = if j == 0 { left[i + 1] } else { cur[j - 1] };
            cur[j] = (diag + s).max(up - 1.0).max(lf - 1.0).max(0.0);
            best = best.max(cur[j]);
        }
        prev_r.copy_from_slice(&cur);
        frontier[i + 1] = cur[bw - 1];
    }
    assert_eq!(out[0].buf.as_f32().unwrap(), &prev_r[..], "prev_row");
    let got_frontier = out[1].buf.as_f32().unwrap();
    assert_eq!(&got_frontier[1..], &frontier[1..], "frontier");
    assert_eq!(out[2].buf.as_f32().unwrap()[0], best, "block max");
}

#[test]
#[ignore = "requires PJRT artifacts + the pjrt feature; see module docs"]
fn validate_artifact_counts_mismatches() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let n = 4096usize;
    let a = rand_f32(7, n);
    let mut b = a.clone();
    b[100] += 1.0;
    b[3000] -= 2.0;
    let out = h
        .execute(
            "validate_n4096",
            vec![Var::f32(&[n], a.clone()), Var::f32(&[n], b)],
        )
        .unwrap();
    assert_eq!(out[0].buf.as_f32().unwrap()[0], 2.0);
    // Checksum of `a` = sum a[i]*(i+1).
    let want: f32 = a.iter().enumerate().map(|(i, x)| x * (i as f32 + 1.0)).sum();
    let got = out[1].buf.as_f32().unwrap()[0];
    assert!((got - want).abs() <= want.abs() * 1e-3 + 1e-2, "{got} vs {want}");
}

#[test]
#[ignore = "requires PJRT artifacts + the pjrt feature; see module docs"]
fn engine_reports_missing_artifacts() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    assert!(h.warm("no_such_artifact").is_err());
    assert!(h.execute("no_such_artifact", vec![]).is_err());
}

#[test]
#[ignore = "requires PJRT artifacts + the pjrt feature; see module docs"]
fn engine_is_shareable_across_threads() {
    let Some(engine) = engine() else { return };
    let h = engine.handle();
    let mut handles = Vec::new();
    for t in 0..4 {
        let h = h.clone();
        handles.push(std::thread::spawn(move || {
            let a = rand_f32(t, 4 * 64);
            let b = rand_f32(t + 10, 64 * 64);
            let out = h
                .execute(
                    "matmul_r4_n64",
                    vec![Var::f32(&[4, 64], a), Var::f32(&[64, 64], b)],
                )
                .unwrap();
            out[0].buf.as_f32().unwrap().len()
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap(), 4 * 64);
    }
}
