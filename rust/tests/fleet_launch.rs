//! End-to-end proof of the self-healing fleet driver: a shard process
//! SIGKILLed mid-sweep is relaunched by `sedar fleet launch`, resumes from
//! its WAL (skipping every task that finished before the kill), and the
//! auto-merged final report is **byte-identical** to the single-process
//! `sedar campaign` run with the same `--seed` — SEDAR's detection +
//! automatic-recovery discipline applied to the validation campaign
//! itself. A partial merge of one live WAL must render a strict prefix
//! (row-wise) of that final report.
//!
//! Everything here goes through the real CLI binary (driver and children
//! alike), so the test covers the spawn/monitor/relaunch/merge path the
//! operator actually runs — not a library approximation of it.
#![cfg(unix)]

use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

/// 32 matmul × sys-ckpt tasks (16 scenarios × both collectives modes):
/// 16 per shard in a 2-way split — enough that the kill below always
/// lands mid-slice (the watcher fires after the *first* durable outcome,
/// leaving 15 tasks of window).
const FILTER: &str = "app=matmul,strategy=sys,scenario=1-16";
const SEED: &str = "11";

/// WAL bytes before the first outcome record: 8 bytes of framing plus the
/// 40-byte sweep-identity header (see `fleet::wal`).
const WAL_HEADER_LEN: u64 = 48;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_sedar")
}

fn tdir() -> PathBuf {
    std::env::temp_dir().join(format!(
        "sedar-fleet-launch-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ))
}

#[test]
fn killed_shard_is_relaunched_and_merged_report_is_byte_identical() {
    let dir = tdir();
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // Reference: the single-process CLI run with the same seed + filter.
    let ref_md = dir.join("ref.md");
    let status = Command::new(bin())
        .args(["campaign", "--seed", SEED, "--filter", FILTER, "--quiet"])
        .args(["--jobs", "2"])
        .arg("--report-out")
        .arg(&ref_md)
        .arg("--run-dir")
        .arg(dir.join("ref-run"))
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .status()
        .unwrap();
    assert!(status.success(), "single-process reference run failed");

    // The fleet: 2 shards under one run directory, driven by the real
    // supervisor. --jobs 1 keeps each shard's slice strictly sequential so
    // the WAL length tracks progress one task at a time.
    let fleet_dir = dir.join("fleet");
    let merged_md = dir.join("merged.md");
    let driver_stdout = dir.join("driver.stdout");
    let driver_stderr = dir.join("driver.stderr");
    let mut driver = Command::new(bin())
        .args(["fleet", "launch", "--shards", "2", "--jobs", "1"])
        .args(["--seed", SEED, "--filter", FILTER, "--poll-ms", "25", "--quiet"])
        .arg("--dir")
        .arg(&fleet_dir)
        .arg("--report-out")
        .arg(&merged_md)
        .stdout(Stdio::from(std::fs::File::create(&driver_stdout).unwrap()))
        .stderr(Stdio::from(std::fs::File::create(&driver_stderr).unwrap()))
        .spawn()
        .unwrap();

    // Watch shard 1's WAL; the per-record sync means a growing file is a
    // truthful progress signal. Once at least one outcome is durable,
    // SIGKILL the shard process named by its pid file — exactly the
    // failure the driver exists to heal.
    let wal = fleet_dir.join("shard-1.wal");
    let pidfile = fleet_dir.join("shard-1.pid");
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        assert!(
            Instant::now() < deadline,
            "shard 1 never logged an outcome"
        );
        assert!(
            driver.try_wait().unwrap().is_none(),
            "driver exited before the kill landed"
        );
        let logged = wal
            .metadata()
            .map(|m| m.len() > WAL_HEADER_LEN)
            .unwrap_or(false);
        if logged && pidfile.exists() {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let pid = std::fs::read_to_string(&pidfile).unwrap().trim().to_string();
    let killed = Command::new("kill").args(["-9", pid.as_str()]).status().unwrap();
    assert!(killed.success(), "kill -9 {pid} failed");

    let status = driver.wait().unwrap();
    let stdout = std::fs::read_to_string(&driver_stdout).unwrap();
    let stderr = std::fs::read_to_string(&driver_stderr).unwrap();
    assert!(
        status.success(),
        "driver failed.\n-- stdout --\n{stdout}\n-- stderr --\n{stderr}"
    );

    // Recovery proof 1: the supervisor noticed the death and relaunched.
    assert!(
        stderr.contains("relaunch"),
        "no relaunch notice in driver stderr:\n{stderr}"
    );
    assert!(
        stdout.contains("1 restart(s)"),
        "launch summary does not report the restart:\n{stdout}"
    );

    // Recovery proof 2: the relaunched incarnation *resumed* — its shard
    // summary line counts WAL-recovered tasks it did not re-execute.
    let shard_log = std::fs::read_to_string(fleet_dir.join("shard-1.log")).unwrap();
    let resumed = shard_log
        .lines()
        .filter_map(|l| {
            let prefix = l.split(" resumed from WAL").next()?;
            if prefix == l {
                return None; // marker absent on this line
            }
            prefix.rsplit(' ').next()?.parse::<usize>().ok()
        })
        .max()
        .unwrap_or(0);
    assert!(
        resumed >= 1,
        "relaunched shard did not resume from its WAL:\n{shard_log}"
    );

    // The headline invariant: the auto-merged report is byte-identical to
    // the single-process run's.
    let reference = std::fs::read(&ref_md).unwrap();
    let merged = std::fs::read(&merged_md).unwrap();
    assert!(!reference.is_empty());
    assert_eq!(
        reference, merged,
        "fleet-launch merged report differs from the single-process run"
    );

    // Exactly one durable file per shard: the run directory holds the two
    // WALs plus the supervisor's pid/log/addr bookkeeping — no journal or
    // artifact siblings.
    for member in 1..=2 {
        assert!(fleet_dir.join(format!("shard-{member}.wal")).exists());
        for relic in ["journal", "bin", "out"] {
            assert!(
                !fleet_dir.join(format!("shard-{member}.{relic}")).exists(),
                "unexpected .{relic} file — the WAL must be the only durable state"
            );
        }
    }

    // The partial-merge contract: one shard's WAL unioned alone (the
    // mid-flight view an operator gets from `sedar merge --allow-partial`)
    // renders per-task rows that all appear in the final merged report.
    let partial_md = dir.join("partial.md");
    let status = Command::new(bin())
        .arg("merge")
        .arg("--allow-partial")
        .arg(&wal)
        .arg("--report-out")
        .arg(&partial_md)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .status()
        .unwrap();
    assert!(status.success(), "partial merge of one shard WAL failed");
    // Markdown cell padding depends on each table's own widest row, so
    // compare trimmed cells and skip the `---` separator row.
    fn per_task_rows(report: &str) -> Vec<String> {
        let start = report.find("## Per task").expect("report has a per-task section");
        let rest = &report[start..];
        let end = rest[1..].find("\n## ").map(|i| i + 1).unwrap_or(rest.len());
        rest[..end]
            .lines()
            .filter(|l| l.starts_with('|') && !l.contains("---"))
            .map(|l| l.split('|').map(str::trim).collect::<Vec<_>>().join("|"))
            .collect()
    }
    let partial = std::fs::read_to_string(&partial_md).unwrap();
    let full = std::fs::read_to_string(&merged_md).unwrap();
    let full_rows = per_task_rows(&full);
    let partial_rows = per_task_rows(&partial);
    assert_eq!(partial_rows.len(), 17, "16 task rows + header");
    for row in &partial_rows {
        assert!(
            full_rows.contains(row),
            "partial-merge row missing from the final report: {row}"
        );
    }

    let _ = std::fs::remove_dir_all(&dir);
}
