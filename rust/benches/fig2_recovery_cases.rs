//! Bench: reproduce **Figure 2** — the two recovery cases of the multiple-
//! system-level-checkpoint strategy, as *live traces*:
//!
//! * (a) detection latency confined within the checkpoint interval → the
//!   last checkpoint is clean, a single rollback recovers;
//! * (b) detection latency transposing the interval → the last checkpoint
//!   is dirty, the same fault re-manifests after restart, and the walk
//!   continues to an older checkpoint.
//!
//! (`cargo bench --bench fig2_recovery_cases`)

use std::sync::Arc;

use sedar::apps::matmul::phases;
use sedar::apps::spec::AppSpec;
use sedar::apps::MatmulApp;
use sedar::config::{RunConfig, Strategy};
use sedar::coordinator::SedarRun;
use sedar::inject::{InjectKind, InjectPoint, InjectionSpec};
use sedar::recovery::ResumeFrom;

fn run_case(label: &str, spec: InjectionSpec) -> sedar::coordinator::RunOutcome {
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(128, 4));
    let mut cfg = RunConfig::for_tests(&format!("fig2-{label}"));
    cfg.strategy = Strategy::SysCkpt;
    let outcome = SedarRun::new(app, cfg, Some(spec)).run().unwrap();
    assert!(outcome.completed);
    assert_eq!(outcome.result_correct, Some(true));
    outcome
}

fn main() {
    // Case (a): fault and detection inside the same interval (after CK3,
    // detected at VALIDATE) — the last checkpoint is valid.
    let a = run_case(
        "a",
        InjectionSpec {
            name: "fig2a".into(),
            point: InjectPoint::BeforePhase(phases::VALIDATE),
            rank: 0,
            replica: 1,
            kind: InjectKind::BitFlip { var: "C".into(), elem: 3, bit: 30 },
        },
    );
    println!("\n=== Figure 2 (a): detection latency within the interval ===\n");
    println!("{}\n", a.summary());
    println!("{}", a.trace_dump);
    assert_eq!(a.restarts, 1);
    assert!(matches!(a.resume_history[0], ResumeFrom::SysCkpt(3)));

    // Case (b): fault before CK3, detected after it — CK3 captured the
    // corruption; restart from CK3 re-manifests; CK2 recovers.
    let b = run_case(
        "b",
        InjectionSpec {
            name: "fig2b".into(),
            point: InjectPoint::BeforePhase(phases::CK3),
            rank: 0,
            replica: 1,
            kind: InjectKind::BitFlip { var: "C".into(), elem: 3, bit: 30 },
        },
    );
    println!("\n=== Figure 2 (b): detection latency transposing the interval ===\n");
    println!("{}\n", b.summary());
    println!("{}", b.trace_dump);
    assert_eq!(b.restarts, 2);
    assert_eq!(b.detections.len(), 2, "the same fault manifests twice");
    assert!(matches!(b.resume_history[0], ResumeFrom::SysCkpt(3)));
    assert!(matches!(b.resume_history[1], ResumeFrom::SysCkpt(2)));

    println!(
        "\ncase (a): 1 rollback in {} — case (b): 2 rollbacks in {} \
         (the extra interval re-execution + restart of Equation 6, k=1)",
        sedar::util::human_duration(a.wall),
        sedar::util::human_duration(b.wall),
    );
}
