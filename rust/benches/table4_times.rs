//! Bench: regenerate **Table 4** — execution times of every SEDAR strategy
//! with/without faults — twice:
//!
//! 1. from the paper's Table-3 parameters (must match the published
//!    numbers to rounding), and
//! 2. from *live runs on this host* (scaled workloads, real injections):
//!    the measured analogue, checked for the paper's orderings.
//!
//! (`cargo bench --bench table4_times`)

use std::sync::Arc;
use std::time::Duration;

use sedar::apps::matmul::phases;
use sedar::apps::spec::AppSpec;
use sedar::apps::MatmulApp;
use sedar::config::{RunConfig, Strategy};
use sedar::coordinator::SedarRun;
use sedar::inject::{InjectKind, InjectPoint, InjectionSpec};
use sedar::model::params::PaperApp;
use sedar::model::tables::table4_markdown;
use sedar::report::Table;

fn main() {
    // ---------------- part 1: the model with the paper's parameters -------
    let cols: Vec<(&str, sedar::model::Params)> = PaperApp::ALL
        .iter()
        .map(|a| (a.label(), a.paper_params()))
        .collect();
    println!("\n=== Table 4 from the paper's Table-3 parameters [hs] ===\n");
    print!("{}", table4_markdown(&cols));

    // ---------------- part 2: live runs on this host ----------------------
    println!("\n=== Table 4 analogue, live runs (matmul N=256, this host) ===\n");
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(256, 4));

    // Faults for the "with fault" rows: early (≈X=30%: corrupt A before
    // SCATTER), mid (B before CK2 → FSC-ish at VALIDATE? use worker B →
    // TDC at GATHER), late (C before VALIDATE → FSC, k=0) and a dirty-CK3
    // double-rollback (k=1 analogue).
    let early = InjectionSpec {
        name: "early".into(),
        point: InjectPoint::BeforePhase(phases::SCATTER),
        rank: 0,
        replica: 1,
        kind: InjectKind::BitFlip { var: "A".into(), elem: (2 * 64 + 1) * 256 + 3, bit: 30 },
    };
    let late_clean = InjectionSpec {
        name: "late-clean".into(),
        point: InjectPoint::BeforePhase(phases::VALIDATE),
        rank: 0,
        replica: 1,
        kind: InjectKind::BitFlip { var: "C".into(), elem: 5, bit: 30 },
    };
    let late_dirty = InjectionSpec {
        name: "late-dirty".into(),
        point: InjectPoint::BeforePhase(phases::CK3),
        rank: 0,
        replica: 1,
        kind: InjectKind::BitFlip { var: "C".into(), elem: 5, bit: 30 },
    };

    let mut t = Table::new(&["situation", "strategy", "wall", "restarts"]);
    let mut record = |label: &str, strategy: Strategy, inj: Option<InjectionSpec>| {
        let mut cfg = RunConfig::for_tests(&format!("t4-{label}-{}", strategy.label()));
        cfg.strategy = strategy;
        let outcome = SedarRun::new(app.clone(), cfg, inj).run().unwrap();
        assert_eq!(outcome.result_correct, Some(true));
        t.row(&[
            label.to_string(),
            strategy.label().to_string(),
            sedar::util::human_duration(outcome.wall),
            outcome.restarts.to_string(),
        ]);
        outcome.wall
    };

    let base_fa = record("no fault", Strategy::Baseline, None);
    let det_fa = record("no fault", Strategy::DetectOnly, None);
    let sys_fa = record("no fault", Strategy::SysCkpt, None);
    let user_fa = record("no fault", Strategy::UserCkpt, None);
    let det_early = record("fault early (X≈30%)", Strategy::DetectOnly, Some(early.clone()));
    let _ = record("fault early (X≈30%)", Strategy::SysCkpt, Some(early));
    let sys_k0 = record("fault late, clean ck (k=0)", Strategy::SysCkpt, Some(late_clean.clone()));
    let sys_k1 = record("fault late, dirty ck (k=1)", Strategy::SysCkpt, Some(late_dirty.clone()));
    let user_fp = record("fault late (1 rollback)", Strategy::UserCkpt, Some(late_dirty));
    let base_fp = record("fault late (vote)", Strategy::Baseline, Some(late_clean));

    print!("\n{}", t.markdown());

    println!("\n=== ordering checks (paper §4.3) ===\n");
    let check = |label: &str, ok: bool| {
        println!("  [{}] {label}", if ok { "ok" } else { "DIFFERS" });
    };
    check("detection overhead is small: det_fa ≈ base_fa", det_fa < base_fa * 3);
    check("ckpt overhead visible but small: sys_fa ≥ det_fa", sys_fa >= det_fa);
    check("k=0 recovery beats detect-only relaunch", sys_k0 < det_early * 2);
    check("k=1 costs more than k=0", sys_k1 > sys_k0);
    check("user-ckpt fp ≈ sys-ckpt fp(k=0) (rows 8 vs 12)", {
        let a = user_fp.as_secs_f64();
        let b = sys_k0.as_secs_f64();
        (a - b).abs() / b.max(a) < 0.9
    });
    check("baseline with fault is the most expensive response", base_fp >= sys_k0);
    let _ = user_fa;
}
