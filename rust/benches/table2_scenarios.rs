//! Bench: regenerate **Table 2** — the 64-scenario workfault with observed
//! effect / P_det / P_rec / N_roll per scenario, plus the recovery wall
//! time per effect class. (`cargo bench --bench table2_scenarios`)

use std::time::Duration;

use sedar::apps::matmul::MatmulApp;
use sedar::config::RunConfig;
use sedar::error::FaultClass;
use sedar::report::Table;
use sedar::workfault;

fn main() {
    let app = MatmulApp::new(64, 4);
    let cfg = RunConfig::for_tests("bench-table2");
    let catalog = workfault::catalog(&app);

    let mut table = Table::new(&[
        "sc", "P_inj", "proc", "data", "effect", "P_det", "P_rec", "N_roll", "observed",
        "wall",
    ]);
    let mut per_class: std::collections::BTreeMap<String, (u32, Duration)> =
        std::collections::BTreeMap::new();
    let mut pass = 0;
    for sc in &catalog {
        let r = workfault::run_scenario(&app, sc, &cfg).expect("scenario run");
        let e = per_class
            .entry(sc.effect.to_string())
            .or_insert((0, Duration::ZERO));
        e.0 += 1;
        e.1 += r.outcome.wall;
        if r.pass {
            pass += 1;
        }
        table.row(&[
            sc.id.to_string(),
            sc.window.label().to_string(),
            if sc.rank == 0 {
                "M".into()
            } else {
                format!("W{}", sc.rank)
            },
            sc.data.label(sc.rank == 0).to_string(),
            sc.effect.to_string(),
            sc.p_det.unwrap_or("-").to_string(),
            sc.p_rec.to_string(),
            sc.n_roll.to_string(),
            if r.pass { "==predicted" } else { "MISMATCH" }.to_string(),
            sedar::util::human_duration(r.outcome.wall),
        ]);
    }

    println!("\n=== Table 2 (all 64 scenarios, predictions vs injection runs) ===\n");
    print!("{}", table.markdown());
    println!("\n{pass}/64 scenarios behave exactly as the §4.1 model predicts.\n");

    let mut sum = Table::new(&["effect class", "scenarios", "mean recovery wall"]);
    for (class, (n, total)) in &per_class {
        sum.row(&[
            class.clone(),
            n.to_string(),
            sedar::util::human_duration(*total / *n),
        ]);
    }
    println!("=== per-class cost summary ===\n");
    print!("{}", sum.markdown());
    let _ = std::fs::remove_dir_all(&cfg.run_dir);
    assert_eq!(pass, 64, "prediction mismatches — see table above");
    let _ = FaultClass::Tdc; // keep the import used in all configs
}
