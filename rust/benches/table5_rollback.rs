//! Bench: regenerate **Table 5** + the §4.4 thresholds — the convenience
//! analysis of multi-rollback recovery vs stop-and-relaunch — from the
//! paper's Jacobi parameters, and verify the decision rule with *live*
//! runs: a fault whose chain walk needs k rollbacks really costs more wall
//! time than one with k-1. (`cargo bench --bench table5_rollback`)

use std::sync::Arc;

use sedar::apps::matmul::phases;
use sedar::apps::spec::AppSpec;
use sedar::apps::MatmulApp;
use sedar::config::{RunConfig, Strategy};
use sedar::coordinator::SedarRun;
use sedar::inject::{InjectKind, InjectPoint, InjectionSpec};
use sedar::model::params::PaperApp;
use sedar::model::tables::{table5, table5_markdown, threshold_x};
use sedar::report::Table;

fn main() {
    // ---------------- the model part --------------------------------------
    let p = PaperApp::Jacobi.paper_params();
    println!("\n=== Table 5 (Jacobi parameters, X ∈ {{30,50,80}}%, k ≤ 4) ===\n");
    print!("{}", table5_markdown(&table5(&p, &[0.3, 0.5, 0.8], 4)));

    println!("\n=== §4.4 crossover thresholds ===\n");
    for (k, want) in [(0u32, 5.88), (1, 22.67), (2, 50.61)] {
        let got = threshold_x(&p, k) * 100.0;
        println!(
            "  X*(k={k}) = {got:5.2}%   (paper: {want}%)  Δ = {:+.2} pp",
            got - want
        );
    }

    // ---------------- the live part ---------------------------------------
    // Same fault class, increasing rollback depth: FSC injections whose
    // dirty-checkpoint span grows — wall time must grow monotonically.
    println!("\n=== live rollback-depth cost (matmul N=256, this host) ===\n");
    let app: Arc<dyn AppSpec> = Arc::new(MatmulApp::new(256, 4));
    let cases = [
        ("k=0 (clean CK3)", phases::VALIDATE, 1u32),
        ("k=1 (dirty CK3)", phases::CK3, 2),
        ("k=3 (dirty CK1..3, A_chunk)", phases::CK1, 4),
    ];
    let mut t = Table::new(&["case", "restarts", "wall"]);
    let mut walls = Vec::new();
    for (label, phase, want_restarts) in cases {
        let var = if phase == phases::CK1 { "A_chunk" } else { "C" };
        let spec = InjectionSpec {
            name: label.into(),
            point: InjectPoint::BeforePhase(phase),
            rank: 0,
            replica: 1,
            kind: InjectKind::BitFlip {
                var: var.into(),
                elem: 7,
                bit: 30,
            },
        };
        let mut cfg = RunConfig::for_tests(&format!("t5-{phase}"));
        cfg.strategy = Strategy::SysCkpt;
        let outcome = SedarRun::new(app.clone(), cfg, Some(spec)).run().unwrap();
        assert_eq!(outcome.result_correct, Some(true));
        assert_eq!(outcome.restarts, want_restarts, "{label}");
        t.row(&[
            label.to_string(),
            outcome.restarts.to_string(),
            sedar::util::human_duration(outcome.wall),
        ]);
        walls.push(outcome.wall);
    }
    print!("{}", t.markdown());
    println!(
        "\n  [{}] wall time grows with rollback depth (the §4.4 cost driver)",
        if walls.windows(2).all(|w| w[1] >= w[0]) {
            "ok"
        } else {
            "DIFFERS (timing noise at this scale)"
        }
    );
}
