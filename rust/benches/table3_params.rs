//! Bench: regenerate **Table 3** — the measured execution parameters of
//! every benchmark application on THIS host (scaled workloads), next to the
//! paper's published values, with the paper's qualitative shape checks:
//!
//! * `f_d` ordering: JACOBI (communication-intensive) ≫ SW ≫ MATMUL;
//! * `t_cs` ordering follows the workload size W: MATMUL > JACOBI > SW;
//! * `T_comp` follows the validated-result size: MATMUL > JACOBI > SW.
//!
//! (`cargo bench --bench table3_params`)

use std::sync::Arc;
use std::time::Duration;

use sedar::apps::spec::AppSpec;
use sedar::apps::{JacobiApp, MatmulApp, SwApp};
use sedar::config::{RunConfig, Strategy};
use sedar::coordinator::SedarRun;
use sedar::model::equations::eq12_f_d;
use sedar::report::Table;

struct Measured {
    t_prog: Duration,
    t_det: Duration,
    f_d: f64,
    t_comp: Duration,
    t_cs: Option<Duration>,
    t_ca: Option<Duration>,
    w_bytes: usize,
}

fn measure(app: Arc<dyn AppSpec>, reps: usize) -> Measured {
    let run = |strategy: Strategy| -> (Duration, sedar::metrics::MetricsSnapshot) {
        let mut best = Duration::MAX;
        let mut snap = None;
        for rep in 0..reps {
            let mut cfg = RunConfig::for_tests(&format!(
                "t3-{}-{}-{rep}",
                app.name(),
                strategy.label()
            ));
            cfg.strategy = strategy;
            let outcome = SedarRun::new(app.clone(), cfg, None).run().unwrap();
            assert_eq!(outcome.result_correct, Some(true));
            if outcome.wall < best {
                best = outcome.wall;
                snap = Some(outcome.metrics);
            }
        }
        (best, snap.unwrap())
    };

    let (t_prog, _) = run(Strategy::Baseline);
    let (t_det, _) = run(Strategy::DetectOnly);
    let (_, sys_m) = run(Strategy::SysCkpt);
    let (_, user_m) = run(Strategy::UserCkpt);

    // T_comp: the final-result comparison cost, measured directly on the
    // result buffer (the paper measures a binary file compare).
    let store = app.init_store(0, 7);
    let result_len = app.expected_result(7).len();
    let a = vec![1.0f32; result_len];
    let b = a.clone();
    let t0 = std::time::Instant::now();
    for _ in 0..100 {
        sedar::report::benchkit::black_box(sedar::detect::buffers_equal(
            unsafe { std::slice::from_raw_parts(a.as_ptr() as *const u8, a.len() * 4) },
            unsafe { std::slice::from_raw_parts(b.as_ptr() as *const u8, b.len() * 4) },
        ));
    }
    let t_comp = t0.elapsed() / 100;

    let f_d = eq12_f_d(t_det.as_secs_f64(), t_prog.as_secs_f64(), t_comp.as_secs_f64());

    Measured {
        t_prog,
        t_det,
        f_d,
        t_comp,
        t_cs: {
            let n = sys_m.sys_ckpts;
            (n > 0).then(|| Duration::from_nanos(sys_m.sys_ckpt_ticks / n))
        },
        t_ca: {
            let n = user_m.user_ckpts;
            (n > 0).then(|| Duration::from_nanos(user_m.user_ckpt_ticks / n))
        },
        w_bytes: store.byte_len() * app.nranks(),
    }
}

fn main() {
    let quick = sedar::report::benchkit::quick();
    let reps = if quick { 3 } else { 7 }; // the paper repeats 5×; we take min
    // Scaled workloads: compute-bound matmul, halo-dominated jacobi,
    // pipeline SW — the paper's three patterns. Sized so T_prog is tens of
    // milliseconds: small enough for CI, large enough that the per-message
    // detection overhead is measured against real compute.
    let apps: Vec<Arc<dyn AppSpec>> = vec![
        Arc::new(MatmulApp::new(256, 4)),
        Arc::new(JacobiApp::new(256, 4, 64, 16)),
        Arc::new(SwApp::new(1024, 4, 64, 4)),
    ];

    let measured: Vec<Measured> = apps.into_iter().map(|a| measure(a, reps)).collect();

    let mut t = Table::new(&[
        "parameter",
        "MATMUL (meas)",
        "JACOBI (meas)",
        "SW (meas)",
        "MATMUL (paper)",
        "JACOBI (paper)",
        "SW (paper)",
    ]);
    let paper: Vec<sedar::model::Params> = sedar::model::params::PaperApp::ALL
        .iter()
        .map(|a| a.paper_params())
        .collect();
    t.row(&[
        "T_prog".into(),
        sedar::util::human_duration(measured[0].t_prog),
        sedar::util::human_duration(measured[1].t_prog),
        sedar::util::human_duration(measured[2].t_prog),
        format!("{:.2} h", paper[0].t_prog / 3600.0),
        format!("{:.2} h", paper[1].t_prog / 3600.0),
        format!("{:.2} h", paper[2].t_prog / 3600.0),
    ]);
    t.row(&[
        "T_det (Eq.3 run)".into(),
        sedar::util::human_duration(measured[0].t_det),
        sedar::util::human_duration(measured[1].t_det),
        sedar::util::human_duration(measured[2].t_det),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    t.row(&[
        "f_d".into(),
        format!("{:.2}%", measured[0].f_d * 100.0),
        format!("{:.2}%", measured[1].f_d * 100.0),
        format!("{:.2}%", measured[2].f_d * 100.0),
        "<0.01%".into(),
        "0.6%".into(),
        "0.05%".into(),
    ]);
    t.row(&[
        "T_comp".into(),
        sedar::util::human_duration(measured[0].t_comp),
        sedar::util::human_duration(measured[1].t_comp),
        sedar::util::human_duration(measured[2].t_comp),
        "42 s".into(),
        "1 s".into(),
        "<1 s".into(),
    ]);
    t.row(&[
        "t_cs".into(),
        measured[0].t_cs.map(sedar::util::human_duration).unwrap_or("-".into()),
        measured[1].t_cs.map(sedar::util::human_duration).unwrap_or("-".into()),
        measured[2].t_cs.map(sedar::util::human_duration).unwrap_or("-".into()),
        "14.10 s".into(),
        "9.62 s".into(),
        "2.55 s".into(),
    ]);
    t.row(&[
        "t_ca".into(),
        measured[0].t_ca.map(sedar::util::human_duration).unwrap_or("-".into()),
        measured[1].t_ca.map(sedar::util::human_duration).unwrap_or("-".into()),
        measured[2].t_ca.map(sedar::util::human_duration).unwrap_or("-".into()),
        "10.58 s".into(),
        "9.11 s".into(),
        "1.92 s".into(),
    ]);
    t.row(&[
        "W (state)".into(),
        sedar::util::human_bytes(measured[0].w_bytes as u64),
        sedar::util::human_bytes(measured[1].w_bytes as u64),
        sedar::util::human_bytes(measured[2].w_bytes as u64),
        "6016 MB".into(),
        "1920 MB".into(),
        "152 MB".into(),
    ]);

    println!("\n=== Table 3 — measured execution parameters (this host) vs paper ===\n");
    print!("{}", t.markdown());

    println!("\n=== shape checks (the paper's qualitative claims) ===\n");
    let shape = |label: &str, ok: bool| {
        println!("  [{}] {label}", if ok { "ok" } else { "DIFFERS" });
    };
    shape(
        "f_d: JACOBI (comm-heavy) is the largest of the three",
        measured[1].f_d >= measured[0].f_d && measured[1].f_d >= measured[2].f_d,
    );
    shape(
        "W: MATMUL > JACOBI > SW (checkpoint size ordering)",
        measured[0].w_bytes > measured[1].w_bytes && measured[1].w_bytes > measured[2].w_bytes,
    );
    shape(
        "t_cs tracks W: MATMUL ≥ SW",
        measured[0].t_cs.unwrap_or_default() >= measured[2].t_cs.unwrap_or_default(),
    );
    shape(
        "T_comp: MATMUL (full matrix) > SW (single score)",
        measured[0].t_comp > measured[2].t_comp,
    );
    println!("\n(absolute values differ from the paper — different machine and scale —\n the orderings are the reproduction target, per DESIGN.md §4.)");
}
