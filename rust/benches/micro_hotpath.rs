//! Bench: the Layer-3 **detection hot path** micro-benchmarks — the perf
//! deliverable's measurement substrate (EXPERIMENTS.md §Perf).
//!
//! Covers: replica-buffer comparison (full vs SHA-256, by message size),
//! borrowed comparison-token construction, pair rendezvous latency, vmpi
//! point-to-point latency/bandwidth, checkpoint frame write/read by codec,
//! VarStore serialization, and — when artifacts are present — the PJRT
//! dispatch overhead.
//!
//! (`cargo bench --bench micro_hotpath`; `SEDAR_BENCH_QUICK=1` shrinks it;
//! `-- --json` suppresses the tables and emits the `sedar-bench/1` JSON
//! document on stdout — what the CI bench-smoke job archives.)

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use sedar::checkpoint::snapshot::{read_frame, write_frame, Codec};
use sedar::detect::{buffers_equal, sha256, Token, ValidationMode};
use sedar::replica::pair::PairSync;
use sedar::report::benchkit::{bench, black_box, print_table, quick, JsonReport, Stats};
use sedar::runtime::Engine;
use sedar::state::{Var, VarStore};
use sedar::util::prng::SplitMix64;
use sedar::vmpi::Network;

fn rand_bytes(seed: u64, n: usize) -> Vec<u8> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| (rng.next_u64() & 0xff) as u8).collect()
}

fn print_stats(echo: bool, title: &str, rows: &[(Stats, Option<usize>)]) {
    if echo {
        print_table(title, rows);
    }
}

fn main() {
    let json = std::env::args().any(|a| a == "--json");
    let echo = !json;
    let mut jr = JsonReport::new();
    jr.meta("bench", "\"micro_hotpath\"");
    jr.meta("quick", if quick() { "true" } else { "false" });
    let iters = if quick() { 20 } else { 200 };

    // ---------------- buffer comparison (the per-message detection cost) --
    let mut rows = Vec::new();
    for size in [1usize << 10, 1 << 14, 1 << 18, 1 << 22] {
        let a = rand_bytes(1, size);
        let b = a.clone();
        rows.push((
            bench(&format!("memcmp-equal {}", sedar::util::human_bytes(size as u64)), 3, iters, || {
                black_box(buffers_equal(&a, &b));
            }),
            Some(size),
        ));
        rows.push((
            bench(&format!("sha256 {}", sedar::util::human_bytes(size as u64)), 3, iters.min(100), || {
                black_box(sha256(&a));
            }),
            Some(size),
        ));
    }
    // Early-exit path: first-byte mismatch must be ~O(1).
    {
        let a = rand_bytes(2, 1 << 22);
        let mut b = a.clone();
        b[0] ^= 1;
        rows.push((
            bench("memcmp-mismatch@0 4MiB", 3, iters, || {
                black_box(buffers_equal(&a, &b));
            }),
            None,
        ));
    }
    for (s, b) in &rows {
        jr.push_stats("compare", s, *b);
    }
    print_stats(echo, "replica-buffer comparison", &rows);
    if echo {
        println!(
            "\ncrossover guidance: full comparison beats hashing at every size on\n\
             this host (compare is bandwidth-bound, sha256 is compute-bound); the\n\
             paper's full-content message validation is the right default, hashes\n\
             pay off only for checkpoint-sized payloads crossing a network."
        );
    }

    // ---------------- comparison-token build (ValidationMode) -------------
    // `Token::new` in Full mode borrows the buffer — the timing asserts the
    // send path allocates nothing for its token.
    let mut rows = Vec::new();
    let msg = rand_bytes(3, 1 << 16);
    rows.push((
        bench("token full 64KiB (borrowed)", 3, iters, || {
            black_box(Token::new(ValidationMode::Full, &msg).len());
        }),
        Some(msg.len()),
    ));
    rows.push((
        bench("token sha256 64KiB", 3, iters, || {
            black_box(Token::new(ValidationMode::Sha256, &msg).len());
        }),
        Some(msg.len()),
    ));
    for (s, b) in &rows {
        jr.push_stats("token", s, *b);
    }
    print_stats(echo, "comparison-token construction", &rows);

    // ---------------- pair rendezvous latency ------------------------------
    {
        let abort = Arc::new(AtomicBool::new(false));
        let pair = PairSync::new(abort);
        let p2 = Arc::clone(&pair);
        let n_rounds = if quick() { 2_000 } else { 20_000 };
        let sibling = std::thread::spawn(move || {
            for _ in 0..n_rounds {
                let _ = p2
                    .exchange(1, vec![1u8; 32].into(), Duration::from_secs(5))
                    .unwrap();
            }
        });
        let s = bench("pair exchange (32 B token)", 0, 1, || {
            for _ in 0..n_rounds {
                let _ = pair
                    .exchange(0, vec![1u8; 32].into(), Duration::from_secs(5))
                    .unwrap();
            }
        });
        sibling.join().unwrap();
        jr.push_raw(format!(
            "{{\"group\":\"rendezvous\",\"case\":\"pair exchange 32B\",\"rounds\":{n_rounds},\
             \"wall_ns\":{},\"ns_per_round\":{:.1}}}",
            s.min.as_nanos(),
            s.min.as_nanos() as f64 / n_rounds as f64
        ));
        if echo {
            println!(
                "\n=== replica rendezvous ===\n\n  {n_rounds} round-trips in {} → {:.2} µs / rendezvous",
                sedar::util::human_duration(s.min),
                s.min.as_secs_f64() * 1e6 / n_rounds as f64
            );
        }
    }

    // ---------------- vmpi point-to-point ----------------------------------
    {
        let net = Network::new(2);
        let a = net.endpoint(0);
        let b = net.endpoint(1);
        let n_msgs = if quick() { 2_000 } else { 20_000 };
        let payload = Var::f32(&[1 << 14], vec![0f32; 1 << 14]); // 64 KiB
        let bytes = (1 << 16) * n_msgs;
        let recv_thread = {
            let b = b.clone();
            std::thread::spawn(move || {
                for _ in 0..n_msgs {
                    let _ = b.recv(0, 1).unwrap();
                }
            })
        };
        // Shared payload: each send clones a reference, not 64 KiB.
        let s = bench("vmpi send+recv 64KiB", 0, 1, || {
            for _ in 0..n_msgs {
                a.send(1, 1, payload.clone()).unwrap();
            }
        });
        recv_thread.join().unwrap();
        jr.push_raw(format!(
            "{{\"group\":\"transport\",\"case\":\"p2p 64KiB\",\"msgs\":{n_msgs},\
             \"wall_ns\":{},\"gib_per_s\":{:.3},\"us_per_msg\":{:.2}}}",
            s.min.as_nanos(),
            bytes as f64 / s.min.as_secs_f64() / (1u64 << 30) as f64,
            s.min.as_secs_f64() * 1e6 / n_msgs as f64
        ));
        if echo {
            println!(
                "\n=== vmpi point-to-point ===\n\n  {n_msgs} × 64 KiB in {} → {:.2} GiB/s, {:.2} µs/msg",
                sedar::util::human_duration(s.min),
                bytes as f64 / s.min.as_secs_f64() / (1u64 << 30) as f64,
                s.min.as_secs_f64() * 1e6 / n_msgs as f64
            );
        }
    }

    // ---------------- snapshot framing -------------------------------------
    let mut rows = Vec::new();
    let dir = std::env::temp_dir().join(format!("sedar-bench-snap-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // A realistic checkpoint body: a rank's matrices (mostly f32 noise,
    // which is the worst case for compression).
    let mut store = VarStore::new();
    let mut rng = SplitMix64::new(9);
    let mut m = vec![0f32; 1 << 20];
    rng.fill_f32(&mut m);
    store.insert("A", Var::f32(&[1024, 1024], m));
    let payload = store.serialize();
    for codec in [Codec::Raw, Codec::Deflate(1), Codec::Deflate(6)] {
        let p = dir.join("frame.bin");
        let label = format!("{codec:?}");
        rows.push((
            bench(&format!("ckpt write {label} 4MiB"), 1, iters.min(30), || {
                write_frame(&p, &payload, codec).unwrap();
            }),
            Some(payload.len()),
        ));
        rows.push((
            bench(&format!("ckpt read  {label} 4MiB"), 1, iters.min(30), || {
                black_box(read_frame(&p).unwrap());
            }),
            Some(payload.len()),
        ));
    }
    rows.push((
        bench("VarStore serialize 4MiB", 1, iters.min(50), || {
            black_box(store.serialize());
        }),
        Some(payload.len()),
    ));
    for (s, b) in &rows {
        jr.push_stats("ckpt_frame", s, *b);
    }
    print_stats(echo, "checkpoint substrate (t_cs drivers)", &rows);
    let _ = std::fs::remove_dir_all(&dir);

    // ---------------- PJRT dispatch ----------------------------------------
    let art = Engine::default_artifact_dir();
    if Engine::artifacts_available(&art) {
        let engine = Engine::start(&art).unwrap();
        let h = engine.handle();
        h.warm("matmul_r4_n64").unwrap();
        let mut rng = SplitMix64::new(11);
        let mut a = vec![0f32; 4 * 64];
        let mut b = vec![0f32; 64 * 64];
        rng.fill_f32(&mut a);
        rng.fill_f32(&mut b);
        let s = bench("engine.execute matmul_r4_n64", 3, iters.min(100), || {
            black_box(
                h.execute(
                    "matmul_r4_n64",
                    vec![Var::f32(&[4, 64], a.clone()), Var::f32(&[64, 64], b.clone())],
                )
                .unwrap(),
            );
        });
        jr.push_stats("pjrt", &s, None);
        if echo {
            println!(
                "\n=== PJRT dispatch (compute hot path) ===\n\n  warm execute: min {} mean {}  \
                 (2·r·n² = {} flop → {:.2} MFLOP/s incl. marshalling)",
                sedar::util::human_duration(s.min),
                sedar::util::human_duration(s.mean),
                2 * 4 * 64 * 64,
                (2.0 * 4.0 * 64.0 * 64.0) / s.min.as_secs_f64() / 1e6
            );
        }
    } else if echo {
        println!("\n(PJRT dispatch bench skipped: no artifacts — run `make artifacts`)");
    }

    if json {
        print!("{}", jr.render());
    }
}
