//! Bench: the **AET-vs-MTBE sweep** (Equations 9–11, §3.4) — the paper's
//! average-execution-time analysis as a series per strategy, for all three
//! parameter sets, plus Daly-optimal checkpoint intervals. This is the
//! "figure" of the temporal model. (`cargo bench --bench fig_aet`)

use sedar::model::params::PaperApp;
use sedar::model::{aet, daly_interval, equations::*, fault_probability};
use sedar::report::Table;

fn main() {
    for app in PaperApp::ALL {
        let p = app.paper_params();
        println!(
            "\n=== AET vs MTBE — {} (T_prog = {:.2} h) [hs] ===\n",
            app.label(),
            p.t_prog / 3600.0
        );
        let mut t = Table::new(&[
            "MTBE [h]",
            "P(fault)",
            "baseline",
            "detect-only",
            "sys-ckpt (k=0)",
            "user-ckpt",
            "winner",
        ]);
        for mtbe_h in [1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 500.0] {
            let mtbe = mtbe_h * 3600.0;
            let rows = [
                aet(eq1_baseline_fa(&p), eq2_baseline_fp(&p), p.t_prog, mtbe),
                aet(eq3_detect_fa(&p), eq4_detect_fp(&p, 0.5), p.t_prog, mtbe),
                aet(eq5_sys_fa(&p), eq6_sys_fp(&p, 0), p.t_prog, mtbe),
                aet(eq7_user_fa(&p), eq8_user_fp(&p), p.t_prog, mtbe),
            ];
            let names = ["baseline", "detect-only", "sys-ckpt", "user-ckpt"];
            let winner = rows
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| names[i])
                .unwrap();
            t.row(&[
                format!("{mtbe_h}"),
                format!("{:.3}", fault_probability(p.t_prog, mtbe)),
                format!("{:.2}", rows[0] / 3600.0),
                format!("{:.2}", rows[1] / 3600.0),
                format!("{:.2}", rows[2] / 3600.0),
                format!("{:.2}", rows[3] / 3600.0),
                winner.to_string(),
            ]);
        }
        print!("{}", t.markdown());
    }

    println!("\n=== shape checks ===\n");
    // At high fault rates the checkpointing strategies must win; at very
    // low rates all strategies converge to their fault-free times and the
    // baseline's (lower fixed overhead) wins by a hair.
    let p = PaperApp::Jacobi.paper_params();
    let high = |t_fa: f64, t_fp: f64| aet(t_fa, t_fp, p.t_prog, 2.0 * 3600.0);
    let sys = high(eq5_sys_fa(&p), eq6_sys_fp(&p, 0));
    let base = high(eq1_baseline_fa(&p), eq2_baseline_fp(&p));
    let det = high(eq3_detect_fa(&p), eq4_detect_fp(&p, 0.5));
    println!(
        "  [{}] MTBE=2h: sys-ckpt ({:.2} h) < detect-only ({:.2} h) < baseline ({:.2} h)",
        if sys < det && det < base { "ok" } else { "DIFFERS" },
        sys / 3600.0,
        det / 3600.0,
        base / 3600.0
    );

    println!("\n=== Daly-optimal checkpoint interval per app ===\n");
    let mut t = Table::new(&["app", "MTBE [h]", "t_cs [s]", "t_opt (Daly)", "paper t_i"]);
    for app in PaperApp::ALL {
        let p = app.paper_params();
        for mtbe_h in [5.0, 24.0, 100.0] {
            t.row(&[
                app.label().to_string(),
                format!("{mtbe_h}"),
                format!("{:.2}", p.t_cs),
                format!("{:.2} h", daly_interval(p.t_cs, mtbe_h * 3600.0) / 3600.0),
                "1 h (fixed)".to_string(),
            ]);
        }
    }
    print!("{}", t.markdown());
}
