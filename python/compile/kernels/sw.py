"""Layer-1 Smith-Waterman row-update Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): GPU Smith-Waterman
implementations parallelize anti-diagonals across threads. On a
vector/VMEM machine the profitable formulation is per-*row* with the
left-to-right gap dependency turned into a **max-plus prefix scan**:

    tmp[j] = max(0, diag[j] + s[j], up[j] + GAP)          (vector op)
    H[j]   = max(tmp[j], max_{k<=j}(tmp[k] + k) - j)      (cummax)

which is exact for a linear gap penalty because every ``tmp`` is already
clamped at 0 (the running clamp never binds — proof in ref.sw_row_ref).
The kernel is one fused vector pass over the band; Layer 2 scans it over
the rows of a block (python/compile/model.py: sw_block).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def sw_row(prev_row, diag_row, left1, s_row, interpret=True):
    """One DP row over a band of width bw.

    Args:
      prev_row: (bw,) H of the previous row.
      diag_row: (bw,) diagonal predecessors (prev shifted, corner in slot 0).
      left1: (1,) H of the left neighbor on this row.
      s_row: (bw,) substitution scores.
    Returns (bw,) H of this row.
    """
    bw = prev_row.shape[0]

    def kernel(prev_ref, diag_ref, left1_ref, s_ref, o_ref):
        tmp = jnp.maximum(diag_ref[...] + s_ref[...], prev_ref[...] + ref.SW_GAP)
        first = jnp.maximum(tmp[0], left1_ref[0] + ref.SW_GAP)
        tmp = jnp.concatenate([first[None], tmp[1:]])
        tmp = jnp.maximum(tmp, 0.0)
        idx = jax.lax.iota(jnp.float32, bw)
        run = jax.lax.cummax(tmp + idx) - idx
        o_ref[...] = jnp.maximum(tmp, run)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((bw,), jnp.float32),
        interpret=interpret,
    )(prev_row, diag_row, left1, s_row)
