"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

Each function here is the *specification* its kernel counterpart is tested
against (pytest + hypothesis sweeps in python/tests/). They are also kept
semantically identical to the rust fallbacks in rust/src/apps/, closing the
loop: rust fallback == jnp reference == Pallas kernel == AOT artifact.
"""

import jax
import jax.numpy as jnp

# Smith-Waterman scoring (matches rust/src/apps/oracle.rs).
SW_MATCH = 2.0
SW_MISMATCH = -1.0
SW_GAP = -1.0


def matmul_ref(a, b):
    """C = A @ B with f32 accumulation: the matmul kernel's oracle."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def jacobi_ref(padded):
    """5-point stencil sweep over a padded (rows+2, n) block.

    Returns the (rows, n) block of neighbor means. Edge columns use a zero
    neighbor outside the block — the caller (rust or model.py) restores the
    Dirichlet boundary afterwards, exactly like the rust fallback.
    """
    rows = padded.shape[0] - 2
    up = padded[0:rows, :]
    down = padded[2 : rows + 2, :]
    mid = padded[1 : rows + 1, :]
    left = jnp.pad(mid[:, :-1], ((0, 0), (1, 0)))
    right = jnp.pad(mid[:, 1:], ((0, 0), (0, 1)))
    return 0.25 * (up + down + left + right)


def sw_row_ref(prev_row, diag_row, left1, s_row):
    """One Smith-Waterman DP row over a band, linear gap.

    Args:
      prev_row: H of the previous row over the band, shape (bw,).
      diag_row: prev_row shifted right by one with the left-neighbor corner
        (H[i-1][band_start-1]) in slot 0 — i.e. the diagonal predecessors.
      left1: scalar H[i][band_start-1] (left neighbor's value on THIS row).
      s_row: substitution scores for this row over the band, shape (bw,).

    The left-to-right dependency H[i][j-1] + GAP is resolved with the
    max-plus prefix trick: H[j] = max_{k<=j} (tmp[k] - (j-k))
                                = prefix_max(tmp[k] + k)[j] - j,
    where tmp[j] = max(0, diag[j]+s[j], up[j]-1, (j==0)*(left1-1)).
    Valid because tmp >= 0 everywhere, so the running clamp never binds.
    """
    bw = prev_row.shape[0]
    tmp = jnp.maximum(diag_row + s_row, prev_row + SW_GAP)
    tmp = tmp.at[0].set(jnp.maximum(tmp[0], left1 + SW_GAP))
    tmp = jnp.maximum(tmp, 0.0)
    idx = jnp.arange(bw, dtype=jnp.float32)
    run = jax.lax.cummax(tmp + idx) - idx
    return jnp.maximum(tmp, run)


def sw_block_ref(s1_block, s2_band, prev_row, left):
    """One (block_rows × band_width) SW DP block — the sw kernel's oracle.

    Args:
      s1_block: (br,) f32 symbols of this row block.
      s2_band: (bw,) f32 symbols of this rank's column band.
      prev_row: (bw,) H of the last processed row.
      left: (br+1,) left-neighbor frontier; left[i] = H[rs-1+i][prev band's
        last column] (zeros for the first band).

    Returns (new_prev_row (bw,), out_frontier (br+1,), block_max (1,)).
    """
    br = s1_block.shape[0]
    bw = s2_band.shape[0]

    def row_step(carry, i):
        prev, best = carry
        s_row = jnp.where(s1_block[i] == s2_band, SW_MATCH, SW_MISMATCH)
        diag = jnp.concatenate([left[i][None], prev[:-1]])
        cur = sw_row_ref(prev, diag, left[i + 1], s_row)
        best = jnp.maximum(best, jnp.max(cur))
        return (cur, best), cur[bw - 1]

    (new_prev, best), last_col = jax.lax.scan(
        row_step, (prev_row, jnp.float32(0.0)), jnp.arange(br)
    )
    out_frontier = jnp.concatenate([prev_row[bw - 1][None], last_col])
    return new_prev, out_frontier, best[None]


def sw_score_ref(s1, s2):
    """Full sequential SW score (numpy-style DP) — end-to-end oracle."""
    import numpy as np

    m, n = len(s1), len(s2)
    prev = np.zeros(n + 1, dtype=np.float32)
    best = 0.0
    for i in range(1, m + 1):
        cur = np.zeros(n + 1, dtype=np.float32)
        for j in range(1, n + 1):
            s = SW_MATCH if s1[i - 1] == s2[j - 1] else SW_MISMATCH
            cur[j] = max(prev[j - 1] + s, prev[j] + SW_GAP, cur[j - 1] + SW_GAP, 0.0)
            best = max(best, cur[j])
        prev = cur
    return np.float32(best)


def validate_ref(a, b):
    """Replica-buffer validation: (mismatch count, weighted checksum).

    The detection hot path's reduce: counts differing elements and returns a
    content checksum of `a`, both as f32 scalars.
    """
    mism = jnp.sum((a != b).astype(jnp.float32))
    idx = jnp.arange(a.shape[0], dtype=jnp.float32) + 1.0
    csum = jnp.sum(a * idx)
    return mism[None], csum[None]
