"""Layer-1 blocked matmul Pallas kernel.

The MATMUL phase of the Master/Worker test application computes
``C_band = A_band @ B`` for a row band of A. The kernel tiles the product
``(bm, bk) x (bk, bn)`` with the k-dimension innermost in the grid, so each
output tile stays resident while the reduction streams through — the
MXU-friendly schedule a TPU build would use (bf16 inputs / f32 accumulator);
under ``interpret=True`` we keep f32 end-to-end so the CPU PJRT path is
bit-deterministic.

VMEM budget (see DESIGN.md §Perf): one (bm, bk) A tile + one (bk, bn) B
tile + one (bm, bn) accumulator; for the default 128³ tiles that is
3 x 64 KiB = 192 KiB << the 16 MiB/core budget, leaving room for
double-buffering the streaming tiles.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim, want):
    """Largest divisor of `dim` not exceeding `want` (shapes here are
    powers of two, so this terminates at a power of two)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


def matmul(a, b, bm=128, bn=128, bk=128, interpret=True):
    """``a @ b`` via a tiled Pallas kernel.

    Args:
      a: (m, k) f32.
      b: (k, n) f32.
      bm/bn/bk: requested tile sizes (clamped to divisors of the dims).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims differ: {k} vs {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)

    def kernel(a_ref, b_ref, o_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
