"""Layer-1 replica-buffer validation Pallas kernel.

The detection hot path compares the two replicas' outgoing message buffers
before every send (§3.1 of the paper). This kernel is the accelerator-side
formulation: a single bandwidth-bound pass producing the mismatch count and
a position-weighted content checksum — the building block for offloaded
(RedMPI-style hashed) validation. The rust coordinator's CPU comparator is
benchmarked against it in benches/micro_hotpath.rs.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim, want):
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


def validate(a, b, bc=4096, interpret=True):
    """Compare two (n,) f32 buffers.

    Returns (mismatches (1,), checksum (1,)): the number of differing
    elements and sum(a[i] * (i+1)).
    """
    n = a.shape[0]
    bc = _pick_block(n, bc)

    def kernel(a_ref, b_ref, m_ref, c_ref):
        @pl.when(pl.program_id(0) == 0)
        def _init():
            m_ref[...] = jnp.zeros_like(m_ref)
            c_ref[...] = jnp.zeros_like(c_ref)

        av = a_ref[...]
        bv = b_ref[...]
        base = pl.program_id(0) * bc
        idx = jax.lax.iota(jnp.float32, bc) + 1.0 + base.astype(jnp.float32)
        m_ref[...] += jnp.sum((av != bv).astype(jnp.float32))[None]
        c_ref[...] += jnp.sum(av * idx)[None]

    grid = (n // bc,)
    spec = pl.BlockSpec((bc,), lambda i: (i,))
    out_spec = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=interpret,
    )(a, b)
