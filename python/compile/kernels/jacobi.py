"""Layer-1 Jacobi 5-point stencil Pallas kernel.

One sweep over a rank's (rows, n) grid block with halo rows attached:
``out[i][j] = 0.25 * (up + down + left + right)``.

Halo handling: the padded (rows+2, n) input is exposed to the kernel as
three row-shifted views (up / mid / down), which keeps every BlockSpec
block-aligned — the interpret-mode-safe equivalent of the overlapping-
window HBM→VMEM schedule a real TPU build would express with unblocked
indexing. The column shifts happen *inside* the kernel on the full-width
row band (shift-and-pad in registers/VMEM), so each grid step touches each
input element exactly once.

Edge columns get a zero outside-neighbor; the caller restores the Dirichlet
boundary afterwards (identical contract to ref.jacobi_ref and the rust
fallback).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim, want):
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


def jacobi_sweep(padded, br=64, interpret=True):
    """One stencil sweep. `padded` is (rows+2, n); returns (rows, n)."""
    rows = padded.shape[0] - 2
    n = padded.shape[1]
    br = _pick_block(rows, br)

    up = padded[0:rows, :]
    mid = padded[1 : rows + 1, :]
    down = padded[2 : rows + 2, :]

    def kernel(up_ref, mid_ref, dn_ref, o_ref):
        m = mid_ref[...]
        left = jnp.pad(m[:, :-1], ((0, 0), (1, 0)))
        right = jnp.pad(m[:, 1:], ((0, 0), (0, 1)))
        o_ref[...] = 0.25 * (up_ref[...] + dn_ref[...] + left + right)

    grid = (rows // br,)
    spec = pl.BlockSpec((br, n), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.float32),
        interpret=interpret,
    )(up, mid, down)
