"""Layer-2 JAX model functions — the compute graphs the rust coordinator
executes through PJRT.

Each function wraps a Layer-1 Pallas kernel in the surrounding compute
structure (scans, boundary handling) and is AOT-lowered by aot.py to an
HLO-text artifact. Every function returns a tuple (the rust loader always
unwraps a tuple — lowering uses return_tuple=True).

Shapes are static per artifact; aot.py emits one artifact per geometry
variant (the rust apps name them, e.g. ``matmul_r16_n256``).
"""

import jax
import jax.numpy as jnp

from .kernels import jacobi as kjacobi
from .kernels import matmul as kmatmul
from .kernels import ref
from .kernels import sw as ksw
from .kernels import validate as kvalidate


def matmul_band(a_band, b):
    """MATMUL phase: C_band = A_band @ B (Pallas tiled matmul)."""
    return (kmatmul.matmul(a_band, b),)


def jacobi_sweep(padded):
    """One Jacobi iteration over a rank's padded block (Pallas stencil).

    The caller (rust) restores the Dirichlet boundary; the artifact computes
    the raw neighbor means, matching the rust fallback's contract.
    """
    return (kjacobi.jacobi_sweep(padded),)


def sw_block(s1_block, s2_band, prev_row, left):
    """One pipelined Smith-Waterman block: scan the Pallas row kernel over
    the block's rows, carrying (prev_row, running max) and emitting the
    frontier column for the next rank.

    Returns (new_prev_row, out_frontier, block_max) — exactly the triple the
    rust SwApp expects.
    """
    br = s1_block.shape[0]
    bw = s2_band.shape[0]

    def row_step(carry, i):
        prev, best = carry
        s_row = jnp.where(s1_block[i] == s2_band, ref.SW_MATCH, ref.SW_MISMATCH)
        diag = jnp.concatenate([left[i][None], prev[:-1]])
        cur = ksw.sw_row(prev, diag, left[i + 1][None], s_row)
        best = jnp.maximum(best, jnp.max(cur))
        return (cur, best), cur[bw - 1]

    (new_prev, best), last_col = jax.lax.scan(
        row_step, (prev_row, jnp.float32(0.0)), jnp.arange(br)
    )
    out_frontier = jnp.concatenate([prev_row[bw - 1][None], last_col])
    return new_prev, out_frontier, best[None]


def validate_buffers(a, b):
    """Replica-buffer validation reduce (Pallas): (mismatches, checksum)."""
    m, c = kvalidate.validate(a, b)
    return m, c
