"""AOT compiler: lower every Layer-2 model function to HLO **text**
artifacts the rust runtime loads through the `xla` crate.

HLO text (not ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Python runs ONCE here, at build time; the rust binary is self-contained
afterwards (Makefile target ``artifacts``).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def variants():
    """(artifact name, function, example args) for every geometry the rust
    apps/examples/benches use. Names must match the rust side:
      MatmulApp::artifact()  -> matmul_r{band_rows}_n{n}
      JacobiApp::artifact()  -> jacobi_r{rows}_n{n}
      SwApp::artifact()      -> sw_b{block_rows}_w{band_width}
    """
    out = []

    # --- matmul: (band_rows, n) ---
    for r, n in [(4, 64), (8, 128), (16, 256), (16, 512), (32, 256)]:
        out.append(
            (f"matmul_r{r}_n{n}", model.matmul_band, (_spec(r, n), _spec(n, n)))
        )

    # --- jacobi: (rows, n), input is the padded (rows+2, n) block ---
    for r, n in [(16, 64), (32, 128), (64, 256)]:
        out.append((f"jacobi_r{r}_n{n}", model.jacobi_sweep, (_spec(r + 2, n),)))

    # --- smith-waterman: (block_rows, band_width) ---
    for br, bw in [(16, 16), (8, 32), (64, 128), (32, 64)]:
        out.append(
            (
                f"sw_b{br}_w{bw}",
                model.sw_block,
                (_spec(br), _spec(bw), _spec(bw), _spec(br + 1)),
            )
        )

    # --- replica-buffer validation reduce ---
    for n in [4096, 65536]:
        out.append((f"validate_n{n}", model.validate_buffers, (_spec(n), _spec(n))))

    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    for name, fn, specs in variants():
        if args.only and args.only not in name:
            continue
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
