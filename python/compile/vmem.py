"""Layer-1 performance model: VMEM footprint + MXU utilization estimates.

interpret=True gives CPU-numpy timings only, which say nothing about TPU
performance — so per the DESIGN.md §Perf plan we optimize kernel *structure*
and estimate the real-hardware characteristics statically:

* **VMEM footprint** per grid step (must fit the ~16 MiB/core budget with
  headroom for double-buffering the streamed operands);
* **MXU utilization** for the matmul kernel: fraction of the 128×128
  systolic array's lanes a (bm, bn, bk) tile keeps busy;
* **arithmetic intensity** (flop / HBM byte), which decides compute- vs
  bandwidth-bound per the roofline.

Run: ``cd python && python -m compile.vmem``
Checked by python/tests/test_perf_model.py, quoted in DESIGN.md §Perf.
"""

VMEM_BUDGET = 16 * 1024 * 1024  # bytes/core
MXU_DIM = 128  # systolic array is 128×128
F32 = 4


def matmul_tiles(m, n, k, bm=128, bn=128, bk=128):
    """VMEM/MXU model of kernels/matmul.py for one (bm,bn,bk) grid step."""
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    vmem = (bm * bk + bk * bn + bm * bn) * F32
    # Each dimension underfills the MXU if the tile is smaller than 128.
    mxu = (min(bm, MXU_DIM) / MXU_DIM) * (min(bn, MXU_DIM) / MXU_DIM)
    flops = 2 * m * n * k
    # Tiled HBM traffic: A read n/bn times, B read m/bm times, C written once.
    hbm = (m * k * (n / bn) + k * n * (m / bm) + m * n) * F32
    return {
        "kind": "matmul",
        "tile": (bm, bn, bk),
        "vmem_bytes": vmem,
        "mxu_util": mxu,
        "flops": flops,
        "hbm_bytes": hbm,
        "intensity": flops / hbm,
    }


def jacobi_tiles(rows, n, br=64):
    """VMEM model of kernels/jacobi.py (bandwidth-bound stencil)."""
    br = min(br, rows)
    # Three (br, n) input views + one output block.
    vmem = 4 * br * n * F32
    flops = 4 * rows * n  # 3 adds + 1 mul per point
    hbm = (3 * rows * n + rows * n) * F32
    return {
        "kind": "jacobi",
        "tile": (br, n),
        "vmem_bytes": vmem,
        "mxu_util": 0.0,  # VPU-only kernel
        "flops": flops,
        "hbm_bytes": hbm,
        "intensity": flops / hbm,
    }


def sw_tiles(br, bw):
    """VMEM model of kernels/sw.py (vector kernel + cummax scan)."""
    # prev, diag, scores, output rows + the left frontier.
    vmem = (4 * bw + br + 1) * F32
    flops = 10 * br * bw  # maxes/adds per cell incl. the prefix scan
    hbm = (br + 3 * bw + 2 * (br + 1)) * F32  # streams once per block
    return {
        "kind": "sw",
        "tile": (br, bw),
        "vmem_bytes": vmem,
        "mxu_util": 0.0,
        "flops": flops,
        "hbm_bytes": hbm,
        "intensity": flops / hbm,
    }


def production_variants():
    """The models for the shipped artifact geometries + the block-shape
    sweep used to pick the matmul defaults (DESIGN.md §Perf)."""
    out = []
    for r, n in [(4, 64), (16, 256), (16, 512)]:
        out.append((f"matmul_r{r}_n{n}", matmul_tiles(r, n, n)))
    # The sweep a real TPU build would choose from: full-MXU tiles.
    for bm, bn, bk in [(128, 128, 128), (256, 256, 64), (512, 128, 128)]:
        out.append(
            (f"matmul_sweep_{bm}x{bn}x{bk}", matmul_tiles(4096, 4096, 4096, bm, bn, bk))
        )
    for r, n in [(16, 64), (64, 256)]:
        out.append((f"jacobi_r{r}_n{n}", jacobi_tiles(r, n)))
    for br, bw in [(16, 16), (64, 128)]:
        out.append((f"sw_b{br}_w{bw}", sw_tiles(br, bw)))
    return out


def main():
    print(f"{'variant':30} {'tile':>16} {'VMEM':>10} {'MXU':>6} {'flop/B':>8}")
    for name, m in production_variants():
        print(
            f"{name:30} {str(m['tile']):>16} {m['vmem_bytes']/1024:>8.1f}K "
            f"{m['mxu_util']*100:>5.0f}% {m['intensity']:>8.2f}"
        )
    print(f"\nVMEM budget/core: {VMEM_BUDGET//1024//1024} MiB "
          f"(double-buffering headroom required: ≤ 1/3 of budget per step)")


if __name__ == "__main__":
    main()
