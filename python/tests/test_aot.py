"""AOT path checks: every variant lowers to parseable HLO text, and the
lowered computation (compiled with plain jax) agrees with the reference —
i.e. what we ship to rust computes the right thing.
"""

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_variant_names_are_unique_and_well_formed():
    names = [name for name, _, _ in aot.variants()]
    assert len(names) == len(set(names))
    for n in names:
        assert n.split("_")[0] in {"matmul", "jacobi", "sw", "validate"}


def test_all_variants_lower_to_hlo_text():
    for name, fn, specs in aot.variants():
        text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text, f"{name}: no entry computation"


def test_matmul_artifact_semantics():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(-1, 1, (4, 64)).astype(np.float32))
    b = jnp.asarray(rng.uniform(-1, 1, (64, 64)).astype(np.float32))
    (got,) = jax.jit(model.matmul_band)(a, b)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5)


def test_jacobi_artifact_semantics():
    rng = np.random.default_rng(1)
    padded = jnp.asarray(rng.uniform(-1, 1, (18, 64)).astype(np.float32))
    (got,) = jax.jit(model.jacobi_sweep)(padded)
    np.testing.assert_allclose(got, ref.jacobi_ref(padded), atol=1e-6)


def test_sw_artifact_semantics():
    rng = np.random.default_rng(2)
    s1 = jnp.asarray(rng.integers(0, 4, 16).astype(np.float32))
    s2 = jnp.asarray(rng.integers(0, 4, 16).astype(np.float32))
    prev = jnp.zeros(16, jnp.float32)
    left = jnp.zeros(17, jnp.float32)
    got = jax.jit(model.sw_block)(s1, s2, prev, left)
    want = ref.sw_block_ref(s1, s2, prev, left)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.array(g), np.array(w), atol=1e-6)


def test_hlo_text_has_no_custom_calls():
    """interpret=True must lower Pallas to plain HLO ops — a Mosaic
    custom-call would be unrunnable on the CPU PJRT client."""
    for name, fn, specs in aot.variants():
        text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
        assert "custom-call" not in text.lower() or "Sharding" in text, (
            f"{name}: contains a custom-call the CPU client cannot run"
        )
