"""Layer-1/Layer-2 performance-structure checks (the perf-pass gates that
CAN be asserted without TPU hardware):

* every shipped kernel variant fits the VMEM budget with double-buffering
  headroom;
* the matmul sweep's chosen production tile saturates the MXU;
* the lowered HLO has the right *structure*: matmul lowers to a real dot,
  the jacobi stencil fuses into elementwise ops (no dot, no convolution
  blow-up), the SW scan lowers to a single while loop (no unrolled row
  explosion), and nothing rematerializes the inputs.
"""

import jax

from compile import aot
from compile.vmem import (
    VMEM_BUDGET,
    jacobi_tiles,
    matmul_tiles,
    production_variants,
    sw_tiles,
)


def hlo_of(name):
    for n, fn, specs in aot.variants():
        if n == name:
            return aot.to_hlo_text(jax.jit(fn).lower(*specs))
    raise KeyError(name)


# ---------------------------------------------------------------- VMEM


def test_all_shipped_variants_fit_vmem_budget():
    for name, m in production_variants():
        assert m["vmem_bytes"] * 3 <= VMEM_BUDGET, (
            f"{name}: {m['vmem_bytes']} B/step leaves no double-buffer room"
        )


def test_production_matmul_tile_saturates_mxu():
    m = matmul_tiles(4096, 4096, 4096, 128, 128, 128)
    assert m["mxu_util"] == 1.0
    # And it is compute-bound on any sane HBM:MXU ratio (> 4 flop/B).
    assert m["intensity"] > 4


def test_small_band_matmul_underfills_mxu_as_expected():
    # The r=4 band kernel is latency-bound by design (tiny per-message
    # blocks in the test app) — the model must report that honestly.
    m = matmul_tiles(4, 64, 64)
    assert m["mxu_util"] < 0.05


def test_stencil_and_sw_are_bandwidth_bound():
    assert jacobi_tiles(64, 256)["intensity"] < 2.0
    assert sw_tiles(64, 128)["intensity"] > 1.0  # DP reuse makes it compute-leaning


# ---------------------------------------------------------------- HLO structure


def test_matmul_lowers_to_dot():
    text = hlo_of("matmul_r16_n256")
    assert " dot(" in text or " dot." in text or "dot(" in text


def test_jacobi_fuses_to_elementwise():
    text = hlo_of("jacobi_r16_n64")
    assert "dot(" not in text, "stencil must not lower to a matmul"
    assert "convolution" not in text
    # Fusion happened: the sweep is a handful of fused adds/multiplies, not
    # hundreds of standalone ops.
    assert text.count("multiply(") + text.count("add(") < 40


def test_sw_scan_stays_compact_loops():
    text = hlo_of("sw_b64_w128")
    # jax.lax.scan lowers to one while loop over the rows (+ at most one
    # more for the cummax prefix scan) — an unrolled version would repeat
    # the row body 64 times.
    n_while = text.count(" while(")
    assert 1 <= n_while <= 2, f"expected 1-2 while loops, found {n_while}"
    # No row-unrolling: the HLO stays compact.
    assert len(text) < 60_000


def test_validate_reduces_to_two_scalars():
    text = hlo_of("validate_n65536")
    assert "reduce(" in text or "reduce." in text
