"""Kernel-vs-reference correctness: the CORE Layer-1 signal.

Every Pallas kernel is checked against its pure-jnp oracle in ref.py, with
hypothesis sweeping shapes and values. Tolerances are tight (the kernels
are f32 end-to-end; matmul allows accumulation-order noise).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import jacobi as kjacobi
from compile.kernels import matmul as kmatmul
from compile.kernels import ref
from compile.kernels import sw as ksw
from compile.kernels import validate as kvalidate

jax.config.update("jax_platform_name", "cpu")

POW2 = [4, 8, 16, 32, 64]


def rand(rng, *shape):
    return jnp.asarray(rng.uniform(-1.0, 1.0, size=shape).astype(np.float32))


# ---------------------------------------------------------------- matmul


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from(POW2),
    k=st.sampled_from(POW2),
    n=st.sampled_from(POW2),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_kernel_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = rand(rng, m, k), rand(rng, k, n)
    got = kmatmul.matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([2, 4, 8]),
    bk=st.sampled_from([2, 4, 16]),
    bn=st.sampled_from([2, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_block_shape_invariance(bm, bk, bn, seed):
    """The result must not depend on the tiling (up to f32 reassociation)."""
    rng = np.random.default_rng(seed)
    a, b = rand(rng, 16, 16), rand(rng, 16, 16)
    got = kmatmul.matmul(a, b, bm=bm, bn=bn, bk=bk)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_matmul_identity():
    a = jnp.eye(8, dtype=jnp.float32)
    b = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    np.testing.assert_array_equal(kmatmul.matmul(a, b), b)


def test_matmul_rejects_bad_shapes():
    a = jnp.zeros((4, 8), jnp.float32)
    b = jnp.zeros((4, 8), jnp.float32)
    with pytest.raises(AssertionError):
        kmatmul.matmul(a, b)


# ---------------------------------------------------------------- jacobi


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([4, 8, 16, 32]),
    n=st.sampled_from([8, 16, 64]),
    br=st.sampled_from([2, 4, 8, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_jacobi_kernel_matches_ref(rows, n, br, seed):
    rng = np.random.default_rng(seed)
    padded = rand(rng, rows + 2, n)
    got = kjacobi.jacobi_sweep(padded, br=br)
    want = ref.jacobi_ref(padded)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_jacobi_constant_grid_interior():
    """A constant field stays constant in the interior of the sweep."""
    padded = jnp.full((10, 16), 3.0, jnp.float32)
    out = kjacobi.jacobi_sweep(padded)
    # Interior columns: mean of 4 equal neighbors = the constant.
    np.testing.assert_allclose(out[:, 1:-1], 3.0, atol=1e-6)


# ---------------------------------------------------------------- smith-waterman


def sw_block_fallback(s1b, s2b, prev, left):
    """Scalar DP, the rust fallback's twin — independent of ref.py."""
    br, bw = len(s1b), len(s2b)
    prev = np.array(prev, dtype=np.float32)
    frontier = np.zeros(br + 1, dtype=np.float32)
    frontier[0] = prev[bw - 1]
    best = np.float32(0.0)
    cur = np.zeros(bw, dtype=np.float32)
    for i in range(br):
        for j in range(bw):
            s = ref.SW_MATCH if s1b[i] == s2b[j] else ref.SW_MISMATCH
            diag = left[i] if j == 0 else prev[j - 1]
            up = prev[j]
            lf = left[i + 1] if j == 0 else cur[j - 1]
            cur[j] = max(diag + s, up + ref.SW_GAP, lf + ref.SW_GAP, 0.0)
            best = max(best, cur[j])
        prev = cur.copy()
        frontier[i + 1] = cur[bw - 1]
    return prev, frontier, np.array([best], dtype=np.float32)


@settings(max_examples=20, deadline=None)
@given(
    br=st.sampled_from([2, 4, 8]),
    bw=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sw_block_ref_matches_scalar_dp(br, bw, seed):
    rng = np.random.default_rng(seed)
    s1b = jnp.asarray(rng.integers(0, 4, br).astype(np.float32))
    s2b = jnp.asarray(rng.integers(0, 4, bw).astype(np.float32))
    prev = jnp.asarray(rng.integers(0, 5, bw).astype(np.float32))
    # A plausible monotone-ish left frontier.
    left = jnp.asarray(rng.integers(0, 5, br + 1).astype(np.float32))
    got = ref.sw_block_ref(s1b, s2b, prev, left)
    want = sw_block_fallback(np.array(s1b), np.array(s2b), prev, np.array(left))
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.array(g), w, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    bw=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sw_row_kernel_matches_ref(bw, seed):
    rng = np.random.default_rng(seed)
    prev = jnp.asarray(rng.integers(0, 6, bw).astype(np.float32))
    diag = jnp.asarray(rng.integers(0, 6, bw).astype(np.float32))
    left1 = jnp.asarray(rng.integers(0, 6, 1).astype(np.float32))
    s_row = jnp.asarray(rng.choice([ref.SW_MATCH, ref.SW_MISMATCH], bw).astype(np.float32))
    got = ksw.sw_row(prev, diag, left1, s_row)
    want = ref.sw_row_ref(prev, diag, left1[0], s_row)
    np.testing.assert_allclose(got, want, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(m=st.sampled_from([8, 16, 24]), seed=st.integers(0, 2**31 - 1))
def test_sw_block_chain_equals_full_dp(m, seed):
    """Chaining blocks through one band reproduces the full SW score."""
    rng = np.random.default_rng(seed)
    s1 = rng.integers(0, 4, m)
    s2 = rng.integers(0, 4, m)
    br = m // 2
    prev = jnp.zeros(m, jnp.float32)
    best = 0.0
    for b in range(2):
        s1b = jnp.asarray(s1[b * br : (b + 1) * br].astype(np.float32))
        left = jnp.zeros(br + 1, jnp.float32)
        prev, _, bmax = ref.sw_block_ref(
            s1b, jnp.asarray(s2.astype(np.float32)), prev, left
        )
        best = max(best, float(bmax[0]))
    assert best == float(ref.sw_score_ref(list(s1), list(s2)))


def test_sw_identical_sequences_score():
    s = jnp.asarray(np.array([0, 1, 2, 3, 0, 1, 2, 3], np.float32))
    prev = jnp.zeros(8, jnp.float32)
    left = jnp.zeros(9, jnp.float32)
    _, _, bmax = ref.sw_block_ref(s, s, prev, left)
    assert float(bmax[0]) == 16.0  # 8 matches × +2


# ---------------------------------------------------------------- validate


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([8, 64, 256]),
    nflips=st.integers(0, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_validate_counts_mismatches(n, nflips, seed):
    rng = np.random.default_rng(seed)
    a = rng.uniform(-1, 1, n).astype(np.float32)
    b = a.copy()
    flip_at = rng.choice(n, size=min(nflips, n), replace=False)
    for i in flip_at:
        b[i] += 1.0
    m, c = kvalidate.validate(jnp.asarray(a), jnp.asarray(b), bc=8)
    wm, wc = ref.validate_ref(jnp.asarray(a), jnp.asarray(b))
    assert float(m[0]) == float(wm[0]) == len(flip_at)
    # Blockwise vs. full-sum accumulation order: absolute tolerance scaled
    # to the summand magnitudes (the checksum can cancel to near zero).
    np.testing.assert_allclose(float(c[0]), float(wc[0]), rtol=1e-4, atol=n * 2e-4)


def test_validate_identical_buffers():
    a = jnp.arange(128, dtype=jnp.float32)
    m, _ = kvalidate.validate(a, a, bc=32)
    assert float(m[0]) == 0.0
